"""The unified result type every scenario run returns.

Whether hit probabilities come from a Monte-Carlo trajectory or from the
working-set fixed point, downstream code (benchmarks, tests, the
EXPERIMENTS.md generator) consumes one :class:`Report`: per-proxy and
per-object hit probabilities, demand-weighted hit rates, ripple/eviction
statistics (simulation only), and throughput. Reports serialize to plain
JSON dicts — that is what ``benchmarks/artifacts/`` records.

Field notes
-----------
* ``hit_prob`` is a dense ``(J, N)`` matrix, except for streaming
  Monte-Carlo runs where it is a
  :class:`~repro.core.fastsim.SparseOccupancy` (indices, values) pair
  over the touched objects — ``dense_hit_prob()`` densifies when N is
  small, ``hit_prob_at_ranks`` probes without densifying.
* ``hit_rate`` (estimated from occupancy, PASTA) and
  ``realized_hit_rate`` (counted hits, Monte-Carlo only; NaN for
  zero-request proxies) are both demand-weighted per proxy.
* ``extras`` carries estimator- and path-specific payloads:
  ``streaming``/``chunk_size`` for streamed runs, solver diagnostics
  for working-set runs, and the full ``admission`` episode (decision
  log, virtual allocations, overbooking gain, predicted-vs-realized
  SLA hit rates) for ``System(admission=...)`` scenarios.
* ``ensemble`` (Monte-Carlo with ``Estimator(replications=R)``) carries
  the per-replica estimates — the main fields become cross-replica
  means and ``hit_prob_ci()`` / ``hit_rate_ci()`` /
  ``overall_hit_rate_ci()`` derive normal-approximation confidence
  bands from it.
* ``same_estimates`` is the round-trip identity check used by the
  JSON tests: estimates must match bit for bit (including the
  per-replica ensemble payload), timing fields are excluded (wall
  clock is not part of a result's identity).
"""

from __future__ import annotations

import statistics as _statistics
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.fastsim import SparseOccupancy


def _z_value(level: float) -> float:
    """Two-sided normal critical value for a confidence ``level``."""
    if not 0.0 < level < 1.0:
        raise ValueError("confidence level must be in (0, 1)")
    return _statistics.NormalDist().inv_cdf(0.5 + level / 2.0)


@dataclass
class ServingReport:
    """Serving-side translation of a block-trace run's counters.

    Built by the serving runner and stored as a plain dict in
    ``Report.extras["serving"]`` (read it back with
    :meth:`Report.serving`). All byte/FLOP figures use the workload's
    ``kv_arch`` KV layout and prefill pricing; with ``kv_arch=None``
    they are in block/"FLOP-unit" terms (1 block = 1 byte = 1 unit).
    """

    tenants: int                     # declared tenants T
    active_tenants: Tuple[int, ...]  # onboarded (all, without admission)
    blocks_per_request: int
    block_tokens: int
    bytes_per_block: float
    kv_arch: Optional[str]
    n_block_events: int              # driven block events (whole trace)
    n_serving_requests: float        # block events / blocks_per_request
    # hit economics
    prefix_hit_block_ratio: float    # resident-block ratio over the trace
    prefix_hit_token_ratio: float    # == block ratio (whole-block hits)
    prefill_tokens_saved: float
    flops_per_token: float
    prefill_flops_saved: float
    # sharing economics (expected, from steady-state occupancy)
    bytes_shared_lb: float           # sum_k l_k * max(0, sum_i occ - 1)
    unshared_equivalent_bytes: float  # sum_{i,k} occ * l_k
    final_virtual_bytes: Optional[Tuple[float, ...]]  # per tenant
    # latency proxy (single-chip roofline prefill of expected miss tokens)
    latency_mean_s: float
    latency_p99_s: float
    latency_cold_s: float            # fully-cold request (no cached prefix)
    admission: Optional[dict] = None  # onboarding episode, when gated

    def to_dict(self) -> dict:
        return {
            "tenants": int(self.tenants),
            "active_tenants": [int(t) for t in self.active_tenants],
            "blocks_per_request": int(self.blocks_per_request),
            "block_tokens": int(self.block_tokens),
            "bytes_per_block": float(self.bytes_per_block),
            "kv_arch": self.kv_arch,
            "n_block_events": int(self.n_block_events),
            "n_serving_requests": float(self.n_serving_requests),
            "prefix_hit_block_ratio": float(self.prefix_hit_block_ratio),
            "prefix_hit_token_ratio": float(self.prefix_hit_token_ratio),
            "prefill_tokens_saved": float(self.prefill_tokens_saved),
            "flops_per_token": float(self.flops_per_token),
            "prefill_flops_saved": float(self.prefill_flops_saved),
            "bytes_shared_lb": float(self.bytes_shared_lb),
            "unshared_equivalent_bytes": float(
                self.unshared_equivalent_bytes
            ),
            "final_virtual_bytes": (
                None
                if self.final_virtual_bytes is None
                else [float(v) for v in self.final_virtual_bytes]
            ),
            "latency_mean_s": float(self.latency_mean_s),
            "latency_p99_s": float(self.latency_p99_s),
            "latency_cold_s": float(self.latency_cold_s),
            "admission": self.admission,
        }


@dataclass
class Report:
    """Unified output of :meth:`repro.scenario.Scenario.run`."""

    scenario: dict               # the spec that produced this report
    estimator: str               # "monte_carlo" | "working_set"
    backend: str                 # engine that ran ("c", "flat", ..., "jax-ws")
    # (J, N) per-proxy per-object hit probability; streaming Monte-Carlo
    # runs carry a SparseOccupancy (indices, values) pair instead —
    # densify with ``dense_hit_prob()`` when N is small. Ensemble runs
    # (Estimator.replications > 1) carry the cross-replica mean here and
    # the per-replica estimates in ``ensemble``.
    hit_prob: "np.ndarray | SparseOccupancy"
    hit_rate: np.ndarray         # (J,) demand-weighted overall hit rate
    overall_hit_rate: float      # request-rate-weighted across proxies
    n_requests: int              # simulated requests (0 for working_set)
    warmup: int
    elapsed_s: float
    throughput_rps: float        # requests/sec through the engine (MC only)
    realized_hit_rate: Optional[np.ndarray] = None  # (J,) counted hits (MC)
    ripple: Optional[dict] = None       # eviction statistics (MC only)
    final_vlen: Optional[np.ndarray] = None
    converged: Optional[bool] = None    # working_set only
    # Ensemble payload (replications > 1): {"replications": R,
    # "batched": bool, "hit_rate": (R, J), "overall_hit_rate": (R,),
    # "realized_hit_rate": (R, J) | None, "hit_prob": (R, J, N) | None
    # (omitted for sparse/streaming runs)}. Main-field estimates are
    # the cross-replica means.
    ensemble: Optional[Dict[str, object]] = None
    extras: Dict[str, object] = field(default_factory=dict)

    # ------------------------------------------------------------------
    # Ensemble accessors (replications > 1)
    # ------------------------------------------------------------------
    @property
    def replications(self) -> int:
        """Ensemble size R (1 for a classic single-trajectory run)."""
        if self.ensemble is None:
            return 1
        return int(self.ensemble["replications"])

    def _require_ensemble(self, what: str) -> None:
        if self.ensemble is None or self.replications < 2:
            raise ValueError(
                f"{what} needs an ensemble run — rerun the scenario with "
                "Estimator(replications=R) for R >= 2"
            )

    def hit_rate_std(self) -> np.ndarray:
        """(J,) cross-replica sample std of the per-proxy hit rates."""
        self._require_ensemble("hit_rate_std()")
        return np.asarray(self.ensemble["hit_rate"], dtype=np.float64).std(
            axis=0, ddof=1
        )

    def hit_rate_ci(
        self, level: float = 0.95
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(mean, lo, hi) normal-approximation CI bands for the
        per-proxy hit rates (each (J,)) — the same shape every CI
        accessor returns."""
        self._require_ensemble("hit_rate_ci()")
        half = (
            _z_value(level)
            * self.hit_rate_std()
            / np.sqrt(self.replications)
        )
        return self.hit_rate, self.hit_rate - half, self.hit_rate + half

    def overall_hit_rate_ci(
        self, level: float = 0.95
    ) -> Tuple[float, float, float]:
        """(mean, lo, hi) for the overall demand-weighted hit rate."""
        self._require_ensemble("overall_hit_rate_ci()")
        vals = np.asarray(
            self.ensemble["overall_hit_rate"], dtype=np.float64
        )
        half = (
            _z_value(level) * vals.std(ddof=1) / np.sqrt(self.replications)
        )
        m = float(vals.mean())
        return m, m - half, m + half

    def hit_prob_std(self) -> np.ndarray:
        """(J, N) cross-replica sample std of per-object hit probs.

        Needs the stacked per-replica ``hit_prob`` in the ensemble
        payload — dropped when the densified ``(R, J, N)`` stack would
        exceed the runner's retention cap (huge-catalogue streaming
        runs), where only the per-proxy statistics are kept.
        """
        self._require_ensemble("hit_prob_std()")
        stack = self.ensemble.get("hit_prob")
        if stack is None:
            raise ValueError(
                "per-replica hit_prob was not retained (the (R, J, N) "
                "stack exceeds the runner's cap) — only per-proxy CI "
                "accessors are available"
            )
        return np.asarray(stack, dtype=np.float64).std(axis=0, ddof=1)

    def hit_prob_ci(
        self, level: float = 0.95
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(mean, lo, hi) per-(proxy, object) hit-probability bands,
        each (J, N) — normal approximation over the R replicas."""
        half = (
            _z_value(level)
            * self.hit_prob_std()
            / np.sqrt(self.replications)
        )
        mean = self.dense_hit_prob()
        return mean, mean - half, mean + half

    # ------------------------------------------------------------------
    @property
    def serving(self) -> Optional[dict]:
        """The serving-metrics payload (``extras["serving"]``), or None
        for non-serving scenarios. See :class:`ServingReport` for the
        field semantics."""
        return self.extras.get("serving")

    @property
    def hit_prob_is_sparse(self) -> bool:
        return isinstance(self.hit_prob, SparseOccupancy)

    def dense_hit_prob(self) -> np.ndarray:
        """The full ``(J, N)`` hit-probability matrix (materializes a
        sparse streaming result — use only when N is small)."""
        if isinstance(self.hit_prob, SparseOccupancy):
            return self.hit_prob.densify()
        return self.hit_prob

    def hit_prob_at_ranks(self, proxy: int, ranks) -> list:
        """Hit probabilities of rank-``r`` objects (1-based, paper style)."""
        if isinstance(self.hit_prob, SparseOccupancy):
            return [
                float(x)
                for x in self.hit_prob.lookup(proxy, [r - 1 for r in ranks])
            ]
        return [float(self.hit_prob[proxy, r - 1]) for r in ranks]

    def to_dict(self) -> dict:
        """JSON-serializable dict (numpy arrays become nested lists)."""
        if isinstance(self.hit_prob, SparseOccupancy):
            hit_prob = {
                "sparse": True,
                "n_objects": int(self.hit_prob.n_objects),
                "indices": self.hit_prob.indices.tolist(),
                "values": self.hit_prob.values.tolist(),
            }
        else:
            hit_prob = self.hit_prob.tolist()
        d = {
            "scenario": self.scenario,
            "estimator": self.estimator,
            "backend": self.backend,
            "hit_prob": hit_prob,
            "hit_rate": self.hit_rate.tolist(),
            "overall_hit_rate": float(self.overall_hit_rate),
            "n_requests": int(self.n_requests),
            "warmup": int(self.warmup),
            "elapsed_s": float(self.elapsed_s),
            "throughput_rps": float(self.throughput_rps),
            "realized_hit_rate": (
                None
                if self.realized_hit_rate is None
                else self.realized_hit_rate.tolist()
            ),
            "ripple": self.ripple,
            "final_vlen": (
                None if self.final_vlen is None else self.final_vlen.tolist()
            ),
            "converged": self.converged,
            "ensemble": (
                None
                if self.ensemble is None
                else {
                    k: (v.tolist() if isinstance(v, np.ndarray) else v)
                    for k, v in self.ensemble.items()
                }
            ),
            "extras": self.extras,
        }
        return d

    @staticmethod
    def from_dict(d: dict) -> "Report":
        def arr(x):
            return None if x is None else np.asarray(x, dtype=np.float64)

        hp = d["hit_prob"]
        if isinstance(hp, dict):
            hit_prob = SparseOccupancy(
                n_objects=int(hp["n_objects"]),
                indices=np.asarray(hp["indices"], dtype=np.int64),
                values=np.asarray(hp["values"], dtype=np.float64),
            )
        else:
            hit_prob = np.asarray(hp, dtype=np.float64)
        ens = d.get("ensemble")
        if ens is not None:
            ens = dict(ens)
            for key in (
                "hit_rate",
                "overall_hit_rate",
                "realized_hit_rate",
                "hit_prob",
            ):
                if ens.get(key) is not None:
                    ens[key] = np.asarray(ens[key], dtype=np.float64)
        return Report(
            scenario=d["scenario"],
            estimator=d["estimator"],
            backend=d["backend"],
            hit_prob=hit_prob,
            hit_rate=np.asarray(d["hit_rate"], dtype=np.float64),
            overall_hit_rate=float(d["overall_hit_rate"]),
            n_requests=int(d["n_requests"]),
            warmup=int(d["warmup"]),
            elapsed_s=float(d["elapsed_s"]),
            throughput_rps=float(d["throughput_rps"]),
            realized_hit_rate=arr(d.get("realized_hit_rate")),
            ripple=d.get("ripple"),
            final_vlen=arr(d.get("final_vlen")),
            converged=d.get("converged"),
            ensemble=ens,
            extras=d.get("extras") or {},
        )

    def same_estimates(self, other: "Report") -> bool:
        """True when the two reports carry identical estimates — the
        round-trip guarantee (timing fields are excluded: wall clock is
        not part of a result's identity)."""
        if self.estimator != other.estimator:
            return False
        a, b = self.hit_prob, other.hit_prob
        if isinstance(a, SparseOccupancy) or isinstance(b, SparseOccupancy):
            sparse = [x for x in (a, b) if isinstance(x, SparseOccupancy)]
            if len(sparse) == 2:
                if not (
                    a.n_objects == b.n_objects
                    and np.array_equal(a.indices, b.indices)
                    and np.array_equal(a.values, b.values)
                ):
                    return False
            else:
                # mixed dense/sparse: compare through densification
                da = a.densify() if isinstance(a, SparseOccupancy) else a
                db = b.densify() if isinstance(b, SparseOccupancy) else b
                if not np.array_equal(da, db):
                    return False
        elif not np.array_equal(a, b):
            return False
        if not np.array_equal(self.hit_rate, other.hit_rate):
            return False
        if self.realized_hit_rate is not None or other.realized_hit_rate is not None:
            if (
                self.realized_hit_rate is None
                or other.realized_hit_rate is None
                # equal_nan: zero-request proxies report NaN by contract
                or not np.array_equal(
                    self.realized_hit_rate,
                    other.realized_hit_rate,
                    equal_nan=True,
                )
            ):
                return False
        if (self.ensemble is None) != (other.ensemble is None):
            return False
        if self.ensemble is not None:
            a, b = self.ensemble, other.ensemble
            if int(a["replications"]) != int(b["replications"]):
                return False
            for key in (
                "hit_rate",
                "overall_hit_rate",
                "realized_hit_rate",
                "hit_prob",
            ):
                va, vb = a.get(key), b.get(key)
                if (va is None) != (vb is None):
                    return False
                if va is not None and not np.array_equal(
                    np.asarray(va, dtype=np.float64),
                    np.asarray(vb, dtype=np.float64),
                    equal_nan=True,
                ):
                    return False
        return self.ripple == other.ripple
