"""`Scenario` — the single declarative entry point over workloads,
systems, and estimators.

One object describes a full experiment of the paper's pipeline (pick a
workload -> pick a sharing policy -> estimate hit probabilities) and
``scenario.run()`` produces one :class:`~repro.scenario.report.Report`
whichever estimator is selected, so Monte-Carlo simulation and the
working-set analytics are interchangeable::

    from repro.scenario import Scenario, System, Workload, Estimator

    sc = Scenario(
        name="demo",
        workload=Workload(alphas=(0.75, 0.5, 1.0), n_objects=1000),
        system=System(allocations=(64, 64, 8), physical_capacity=1000),
        estimator=Estimator("monte_carlo"),
        n_requests=1_000_000,
    )
    sim = sc.run()
    ws = sc.with_estimator("working_set").run()

Scenarios round-trip through JSON (``to_json`` / ``from_json`` /
``save`` / ``load``): rerunning a loaded scenario with the same seed
reproduces the same Report estimates bit for bit.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field, replace
from pathlib import Path
from typing import Optional

from .report import Report
from .system import Estimator, System
from .workload import Workload

SCHEMA_VERSION = 1


@dataclass(frozen=True)
class Scenario:
    """A named, serializable experiment specification."""

    name: str
    workload: Workload
    system: System
    estimator: Estimator = field(default_factory=Estimator)
    n_requests: int = 0       # 0 + trace workload = replay the full trace
    warmup: Optional[int] = None      # None = default_warmup heuristic
    ripple_from: Optional[int] = None  # None = warmup
    seed: int = 0
    description: str = ""

    def __post_init__(self) -> None:
        if self.workload.kind != "trace" and self.n_requests < 1:
            if self.estimator.kind == "monte_carlo":
                raise ValueError("monte_carlo scenarios need n_requests >= 1")
        wj = self.workload.n_proxies
        sj = self.system.n_proxies
        if wj != sj:
            raise ValueError(
                f"workload has {wj} proxies but system has {sj} allocations"
            )

    # ------------------------------------------------------------------
    def run(self) -> Report:
        """Produce a Report with the configured estimator."""
        from .runner import run_scenario

        return run_scenario(self)

    # ------------------------------------------------------------------
    def with_estimator(self, kind: str, **kw) -> "Scenario":
        """Same experiment, different estimator (e.g. swap ``monte_carlo``
        for ``working_set`` to compare Table I against Table II)."""
        return replace(self, estimator=Estimator(kind=kind, **kw))

    def scaled(self, requests: float = 1.0, catalogue: float = 1.0) -> "Scenario":
        """Shrink (or grow) the experiment while keeping its shape.

        ``requests`` scales the trace length (and warmup, when pinned);
        ``catalogue`` scales the object population together with every
        allocation/capacity so the b/N operating regime is preserved.
        This is what replaces the old ``REPRO_FULL``/``REPRO_QUICK``
        per-benchmark forks: presets are defined at paper scale and the
        harness dials them down.

        Trace-replay workloads cannot be rescaled (their catalogue and
        request stream are fixed recordings): catalogue scaling would
        shrink the system against an unshrunk trace, so it raises, as
        does requests scaling of a full-trace (``n_requests=0``) replay
        — set ``n_requests`` to a prefix length explicitly instead.
        """
        if self.workload.kind == "trace":
            if catalogue != 1.0:
                raise ValueError(
                    "cannot catalogue-scale a trace-replay scenario: the "
                    "recorded trace keeps its object population"
                )
            if requests != 1.0 and not self.n_requests:
                raise ValueError(
                    "cannot requests-scale a full-trace replay "
                    "(n_requests=0); set n_requests to a prefix length"
                )
        kw = {}
        if requests != 1.0:
            if self.n_requests:
                kw["n_requests"] = max(1, round(self.n_requests * requests))
            if self.warmup is not None:
                kw["warmup"] = max(0, round(self.warmup * requests))
            if self.ripple_from is not None and self.ripple_from > 0:
                kw["ripple_from"] = max(0, round(self.ripple_from * requests))
        wl = self.workload.scaled(requests, catalogue)
        sy = self.system.scaled(catalogue)
        if wl is not self.workload:
            kw["workload"] = wl
        if sy is not self.system:
            kw["system"] = sy
        return replace(self, **kw) if kw else self

    # ------------------------------------------------------------------
    # JSON round-trip
    # ------------------------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "schema": SCHEMA_VERSION,
            "name": self.name,
            "description": self.description,
            "workload": self.workload.to_dict(),
            "system": self.system.to_dict(),
            "estimator": self.estimator.to_dict(),
            "n_requests": self.n_requests,
            "warmup": self.warmup,
            "ripple_from": self.ripple_from,
            "seed": self.seed,
        }

    @staticmethod
    def from_dict(d: dict) -> "Scenario":
        schema = d.get("schema", SCHEMA_VERSION)
        if schema != SCHEMA_VERSION:
            raise ValueError(f"unsupported scenario schema {schema}")
        return Scenario(
            name=d["name"],
            description=d.get("description", ""),
            workload=Workload.from_dict(d["workload"]),
            system=System.from_dict(d["system"]),
            estimator=Estimator.from_dict(d.get("estimator") or {}),
            n_requests=int(d.get("n_requests", 0)),
            warmup=d.get("warmup"),
            ripple_from=d.get("ripple_from"),
            seed=int(d.get("seed", 0)),
        )

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 2)
        return json.dumps(self.to_dict(), **kw)

    @staticmethod
    def from_json(s: str) -> "Scenario":
        return Scenario.from_dict(json.loads(s))

    def save(self, path) -> Path:
        path = Path(path)
        path.write_text(self.to_json() + "\n")
        return path

    @staticmethod
    def load(path) -> "Scenario":
        return Scenario.from_json(Path(path).read_text())
