"""The Workload axis of a :class:`repro.scenario.Scenario`.

A :class:`Workload` declaratively describes the request process and the
object-size population that drive an experiment:

* ``kind="irm"`` — the paper's stationary Independent Reference Model:
  per-proxy Zipf popularity (heterogeneous ``alphas``, optional
  ``proxy_rates``) over one shared object ranking.
* ``kind="shot_noise"`` — non-stationary catalogue churn in the spirit of
  shot-noise traffic models (cf. Olmos et al., "Cache Miss Estimation for
  Non-Stationary Request Processes"): the per-proxy Zipf *profile* is
  fixed but the identity of the popular objects rotates by
  ``phase_shift`` ranks every ``phase_requests`` requests, so fresh
  objects keep displacing the head of the popularity curve.
* ``kind="trace"`` — explicit replay of a recorded (proxy, object)
  stream; request rates for the analytic estimator are recovered
  empirically from the trace itself.
* ``kind="tenant_churn"`` — a multi-tenant *episode* for the Section
  IV-C admission-control runner: each entry of ``alphas`` is one
  prospective tenant, and ``tenant_events`` is a stream of
  ``(round, "arrive" | "depart", tenant)`` events. Each round, the
  active tenants generate ``round_requests`` IRM requests that feed the
  operator's online popularity estimates. Requires
  ``System(admission=...)`` — the event stream is driven by the
  admission runner, not by ``sample()``.
* ``kind="serving"`` — multi-tenant LLM prompt streams compiled to a
  block trace (see :mod:`repro.serving.trace`): each tenant (one entry
  of ``alphas`` = its Zipf exponent over a per-tenant prompt catalogue)
  draws prompts whose hottest ``shared_frac`` fraction are shared
  system-prompt/few-shot prefixes; every request expands to
  ``prefix_blocks + suffix_blocks`` chained block objects, so prefix
  residency runs on the fastsim backends. ``n_objects`` is *derived*
  from the geometry; ``n_requests`` counts block events. Lengths are
  whole blocks (unit); byte/FLOP metrics come from ``kv_arch``'s KV
  layout in ``Report.extras["serving"]``.

Object lengths come from a :class:`LengthSpec` (unit, fixed, Zipf-ranked,
lognormal, or explicit), sampled deterministically from the scenario
seed. Everything is JSON-serializable via ``to_dict``/``from_dict``.
"""

from __future__ import annotations

from dataclasses import asdict, dataclass, field, replace
from functools import cached_property
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.irm import (
    IRMTrace,
    rate_matrix,
    sample_trace,
    sample_trace_chunks,
)
from repro.serving.trace import (
    ServingLayout,
    compile_trace,
    iter_event_batches,
    serving_rates,
)

LENGTH_KINDS = ("unit", "fixed", "zipf", "lognormal", "explicit")
WORKLOAD_KINDS = ("irm", "shot_noise", "trace", "tenant_churn", "serving")
TENANT_ACTIONS = ("arrive", "depart")


@dataclass(frozen=True)
class LengthSpec:
    """Object-size population l_1..l_N.

    * ``unit`` — every object has length 1 (the paper's Section V setup).
    * ``fixed`` — every object has length ``value``.
    * ``zipf`` — length falls with popularity rank:
      ``l_k = clip(round(max_len * k^-beta), 1, max_len)`` (popular
      objects big — the adversarial case for sharing).
    * ``lognormal`` — i.i.d. ``round(exp(N(mu, sigma)))`` clipped to
      ``[1, max_len]``, seeded from the scenario seed.
    * ``explicit`` — ``values`` gives one length per object.
    """

    kind: str = "unit"
    value: int = 1
    beta: float = 0.5
    max_len: int = 8
    mu: float = 0.0
    sigma: float = 0.5
    values: Optional[Tuple[int, ...]] = None

    def __post_init__(self) -> None:
        if self.kind not in LENGTH_KINDS:
            raise ValueError(
                f"unknown length kind {self.kind!r}; options: {LENGTH_KINDS}"
            )
        if self.kind == "explicit" and not self.values:
            raise ValueError("explicit length spec needs values")

    def materialize(self, n_objects: int, seed: int) -> np.ndarray:
        """(N,) positive int64 lengths, deterministic in (spec, seed)."""
        if self.kind == "unit":
            return np.ones(n_objects, dtype=np.int64)
        if self.kind == "fixed":
            if self.value < 1:
                raise ValueError("fixed length must be positive")
            return np.full(n_objects, int(self.value), dtype=np.int64)
        if self.kind == "zipf":
            ranks = np.arange(1, n_objects + 1, dtype=np.float64)
            l = np.round(self.max_len * ranks ** (-self.beta))
            return np.clip(l, 1, self.max_len).astype(np.int64)
        if self.kind == "lognormal":
            rng = np.random.default_rng(seed ^ 0x5EED1E)
            l = np.round(np.exp(rng.normal(self.mu, self.sigma, n_objects)))
            return np.clip(l, 1, self.max_len).astype(np.int64)
        values = np.asarray(self.values, dtype=np.int64)
        if len(values) != n_objects:
            raise ValueError(
                f"explicit lengths: {len(values)} values for {n_objects} objects"
            )
        if (values < 1).any():
            raise ValueError("object lengths must be positive")
        return values


@dataclass(frozen=True)
class Workload:
    """Declarative request process over ``n_objects`` shared objects.

    Fields
    ------
    kind:
        ``irm``, ``shot_noise``, ``trace``, or ``tenant_churn`` (see the
        module docstring for the semantics of each).
    n_objects:
        Catalogue size N; all proxies draw from the same object ranking
        (that is what makes objects shareable).
    alphas:
        Per-proxy Zipf exponents — one entry per proxy (``irm`` /
        ``shot_noise``) or per prospective tenant (``tenant_churn``).
    proxy_rates:
        Optional per-proxy total request-rate scaling (default: every
        proxy has rate 1, the paper's normalized setting).
    lengths:
        Object-size population (:class:`LengthSpec`), sampled
        deterministically from the scenario seed.
    phase_requests / phase_shift:
        ``shot_noise`` only — stationary-phase length (requests) and
        per-phase popularity-rank rotation.
    trace_proxies / trace_objects / trace_proxy_count:
        ``trace`` replay only — the recorded (proxy, object) stream;
        ``trace_proxy_count`` declares the true number of proxies when
        the highest-numbered ones are silent in the recording (default:
        max observed id + 1).
    tenant_events:
        ``tenant_churn`` only — tuple of ``(round, action, tenant)``
        events with ``action`` in ``("arrive", "depart")``; defaults to
        every tenant arriving at round 0. Each tenant arrives at most
        once and may depart at most once, strictly after its arrival
        round.
    round_requests:
        ``tenant_churn`` only — estimation requests sampled from the
        active tenants each round (the traffic the operator's
        :class:`~repro.core.irm.PopularityEstimator` sees).
    n_prompts / shared_frac / prefix_blocks / suffix_blocks /
    suffix_choices:
        ``serving`` only — per-tenant prompt-catalogue size, fraction of
        it (the head ranks) drawn from the shared prefix pool, blocks
        per prompt-prefix chain, blocks per user-suffix tail, and the
        finite per-(tenant, prompt) suffix population. ``n_objects`` is
        derived from this geometry (every block-aligned chain position
        is one object); construction overwrites whatever was passed.
    kv_arch / block_tokens:
        ``serving`` only — model architecture name (``repro.configs``)
        and tokens per KV block, used by the serving report to price
        blocks in bytes (``kv_layout``) and cached tokens in prefill
        FLOPs. ``kv_arch=None`` keeps unit pricing (1 block = 1 byte =
        1 FLOP-unit).
    """

    kind: str = "irm"
    n_objects: int = 1000
    alphas: Tuple[float, ...] = (0.75, 0.5, 1.0)
    proxy_rates: Optional[Tuple[float, ...]] = None
    lengths: LengthSpec = field(default_factory=LengthSpec)
    # shot_noise only: stationary-phase length and per-phase rank rotation
    phase_requests: int = 0
    phase_shift: int = 0
    # trace replay only; trace_proxy_count declares the true number of
    # proxies when the highest-numbered ones are silent in the recording
    # (default: max observed id + 1)
    trace_proxies: Optional[Tuple[int, ...]] = None
    trace_objects: Optional[Tuple[int, ...]] = None
    trace_proxy_count: Optional[int] = None
    # tenant_churn only: (round, action, tenant) events + estimation
    # traffic per round
    tenant_events: Optional[Tuple[Tuple[int, str, int], ...]] = None
    round_requests: int = 0
    # serving only: prompt-stream geometry (n_objects is derived)
    n_prompts: int = 0
    shared_frac: float = 0.0
    prefix_blocks: int = 0
    suffix_blocks: int = 0
    suffix_choices: int = 1
    kv_arch: Optional[str] = None
    block_tokens: int = 16

    def __post_init__(self) -> None:
        if self.kind not in WORKLOAD_KINDS:
            raise ValueError(
                f"unknown workload kind {self.kind!r}; options: {WORKLOAD_KINDS}"
            )
        if self.n_objects < 1:
            raise ValueError("need at least one object")
        if self.kind == "shot_noise" and (
            self.phase_requests < 1 or self.phase_shift < 1
        ):
            raise ValueError(
                "shot_noise needs phase_requests >= 1 and phase_shift >= 1"
            )
        if self.kind == "tenant_churn":
            if self.round_requests < 1:
                raise ValueError("tenant_churn needs round_requests >= 1")
            self._check_tenant_events()
        if self.kind == "serving":
            if self.lengths.kind != "unit":
                raise ValueError(
                    "serving workloads account in whole KV blocks (unit "
                    "lengths); byte metrics come from kv_arch's layout "
                    "in the serving report"
                )
            if self.kv_arch is not None:
                from repro.configs import get_config

                get_config(self.kv_arch)   # raises on unknown arch
                if self.block_tokens < 1:
                    raise ValueError("block_tokens must be >= 1")
            # geometry validation + the derived catalogue size
            object.__setattr__(
                self, "n_objects", self.serving_layout().n_objects
            )
        if self.kind == "trace":
            if self.trace_proxies is None or self.trace_objects is None:
                raise ValueError("trace workload needs trace_proxies/objects")
            if len(self.trace_proxies) != len(self.trace_objects):
                raise ValueError("trace proxies/objects length mismatch")
            # Range-check here, not in the engines: the C drive loop
            # indexes raw ids without bounds checks, so a corrupt
            # artifact must be rejected at construction. (The upper
            # proxy bound is the system's to enforce — Scenario matches
            # n_proxies against the allocation vector.)
            if self.trace_proxies and min(self.trace_proxies) < 0:
                raise ValueError("trace proxy ids must be nonnegative")
            if self.trace_objects and not (
                0 <= min(self.trace_objects)
                and max(self.trace_objects) < self.n_objects
            ):
                raise ValueError(
                    f"trace object ids must be in [0, {self.n_objects})"
                )
            if self.trace_proxy_count is not None:
                observed = (
                    max(self.trace_proxies) + 1 if self.trace_proxies else 0
                )
                if self.trace_proxy_count < observed:
                    raise ValueError(
                        f"trace_proxy_count={self.trace_proxy_count} < "
                        f"{observed} observed proxies"
                    )
        elif not self.alphas:
            raise ValueError("need at least one proxy alpha")

    def _check_tenant_events(self) -> None:
        """Validate the tenant_churn event stream at construction."""
        T = len(self.alphas)
        arrived: Dict[int, int] = {}
        departed: Dict[int, int] = {}
        for ev in self.events():
            r, action, tenant = ev
            if action not in TENANT_ACTIONS:
                raise ValueError(
                    f"unknown tenant action {action!r}; "
                    f"options: {TENANT_ACTIONS}"
                )
            if not 0 <= tenant < T:
                raise ValueError(
                    f"tenant id {tenant} out of range [0, {T})"
                )
            if r < 0:
                raise ValueError("event rounds must be nonnegative")
            if action == "arrive":
                if tenant in arrived:
                    raise ValueError(f"tenant {tenant} arrives twice")
                arrived[tenant] = r
            else:
                if tenant in departed:
                    raise ValueError(f"tenant {tenant} departs twice")
                # strictly after the arrival round: a same-round pair
                # would be reordered by events_by_round (departures
                # first) and the departure silently dropped.
                if tenant not in arrived or r <= arrived[tenant]:
                    raise ValueError(
                        f"tenant {tenant} must depart in a later round "
                        "than it arrives"
                    )
                departed[tenant] = r

    # ------------------------------------------------------------------
    @property
    def n_proxies(self) -> int:
        if self.kind == "trace":
            if self.trace_proxy_count is not None:
                return int(self.trace_proxy_count)
            return int(max(self.trace_proxies)) + 1 if self.trace_proxies else 1
        return len(self.alphas)

    # -- serving geometry ----------------------------------------------
    def serving_layout(self) -> ServingLayout:
        """Object-space geometry of a ``serving`` workload (validates)."""
        if self.kind != "serving":
            raise ValueError(f"not a serving workload: kind={self.kind!r}")
        return ServingLayout(
            n_tenants=len(self.alphas),
            n_prompts=self.n_prompts,
            shared_frac=self.shared_frac,
            prefix_blocks=self.prefix_blocks,
            suffix_blocks=self.suffix_blocks,
            suffix_choices=self.suffix_choices,
        )

    # -- tenant_churn episode structure --------------------------------
    def events(self) -> Tuple[Tuple[int, str, int], ...]:
        """The normalized tenant-event stream, sorted by round (stable:
        ties keep their declared order). Default: every tenant arrives
        at round 0."""
        if self.tenant_events is None:
            return tuple((0, "arrive", t) for t in range(len(self.alphas)))
        return tuple(
            sorted(
                ((int(r), a, int(t)) for r, a, t in self.tenant_events),
                key=lambda ev: ev[0],
            )
        )

    @property
    def n_rounds(self) -> int:
        """Number of episode rounds (last event round + 1)."""
        evs = self.events()
        return (max(ev[0] for ev in evs) + 1) if evs else 0

    def events_by_round(self) -> Dict[int, List[Tuple[str, int]]]:
        """{round: [(action, tenant), ...]} with departures ordered
        before arrivals inside each round (departures free headroom the
        same-round arrivals may need)."""
        out: Dict[int, List[Tuple[str, int]]] = {}
        for r, action, tenant in self.events():
            out.setdefault(r, []).append((action, tenant))
        for evs in out.values():
            evs.sort(key=lambda e: 0 if e[0] == "depart" else 1)
        return out

    def rates(self) -> np.ndarray:
        """(J, N) stationary request-rate matrix.

        For ``irm`` this is the exact Zipf rate matrix; for ``trace`` the
        empirical per-(proxy, object) request frequencies; ``shot_noise``
        has no single stationary matrix — use :meth:`mean_rates`. The
        matrix is computed once per Workload instance and cached (the
        runner needs it both to sample the trace and to weight hit
        rates; at Fig.-2 scale it is a 9x1e6 array). Treat it as
        read-only.
        """
        return self._rates

    @cached_property
    def _rates(self) -> np.ndarray:
        if self.kind == "trace":
            return self._empirical_rates(len(self.trace_proxies))
        if self.kind == "serving":
            return serving_rates(
                self.serving_layout(), self.alphas, self.proxy_rates
            )
        return rate_matrix(self.n_objects, list(self.alphas), self.proxy_rates)

    def _empirical_rates(self, n: int) -> np.ndarray:
        """Per-(proxy, object) request frequencies over the first ``n``
        requests of the embedded trace."""
        J, N = self.n_proxies, self.n_objects
        lam = np.zeros((J, N), dtype=np.float64)
        np.add.at(
            lam,
            (
                np.asarray(self.trace_proxies[:n]),
                np.asarray(self.trace_objects[:n]),
            ),
            1.0,
        )
        return lam / max(n, 1)

    def mean_rates(self, n_requests: int) -> np.ndarray:
        """Time-average (J, N) rate matrix over ``n_requests`` requests.

        Equals :meth:`rates` for the stationary IRM. For ``trace`` it
        counts frequencies over exactly the replayed prefix (a replay of
        half the trace is weighted by the mix it actually saw). For
        ``shot_noise`` it averages the rotated per-phase matrices — the
        input the working-set estimator sees (it approximates the churn
        by its long-run popularity mixture).
        """
        if self.kind == "trace":
            n = min(n_requests, len(self.trace_proxies))
            if n == len(self.trace_proxies):
                return self.rates()
            return self._empirical_rates(n)
        lam = self.rates()
        if self.kind != "shot_noise":
            return lam
        n_requests = max(n_requests, 1)
        n_phases = -(-n_requests // self.phase_requests)
        N = self.n_objects
        acc = np.zeros_like(lam)
        for p in range(n_phases):
            # duration-weighted: the last phase may be partial
            dur = min(self.phase_requests, n_requests - p * self.phase_requests)
            acc += (dur / n_requests) * np.roll(
                lam, (p * self.phase_shift) % N, axis=1
            )
        return acc

    # ------------------------------------------------------------------
    def _rotate(self, objects: np.ndarray, start: int) -> np.ndarray:
        """Apply the shot-noise per-phase rank rotation in place."""
        phases = (start + np.arange(len(objects))) // self.phase_requests
        return (objects + phases * self.phase_shift) % self.n_objects

    def sample(self, n_requests: int, seed: int) -> IRMTrace:
        """Materialize a merged trace of ``n_requests`` requests.

        The most recent (n_requests, seed) draw is cached on the
        instance, so sweeps that rerun many systems over one shared
        workload (e.g. ``benchmarks/bench_rre.py``) sample once. Treat
        the returned trace as read-only.
        """
        key = (n_requests, seed)
        if self.__dict__.get("_trace_key") == key:
            return self.__dict__["_trace_val"]
        t = self._sample(n_requests, seed)
        self.__dict__["_trace_key"] = key
        self.__dict__["_trace_val"] = t
        return t

    def _sample(self, n_requests: int, seed: int) -> IRMTrace:
        if self.kind == "tenant_churn":
            raise ValueError(
                "tenant_churn workloads are driven round-by-round by the "
                "admission runner (System(admission=...)); they have no "
                "single merged trace"
            )
        if self.kind == "trace":
            P = np.asarray(self.trace_proxies, dtype=np.int32)
            O = np.asarray(self.trace_objects, dtype=np.int64)
            if n_requests > len(P):
                raise ValueError(
                    f"trace has {len(P)} requests, {n_requests} asked"
                )
            return IRMTrace(P[:n_requests], O[:n_requests])
        if self.kind == "serving":
            p, o = compile_trace(
                self.serving_layout(), self.alphas, self.proxy_rates,
                n_requests, seed,
            )
            return IRMTrace(p, o)
        t = sample_trace(self.rates(), n_requests, seed=seed)
        if self.kind == "shot_noise":
            return IRMTrace(t.proxies, self._rotate(t.objects, 0))
        return t

    def iter_chunks(
        self, n_requests: int, seed: int, *, chunk_size: int = 1_000_000
    ) -> Iterator[IRMTrace]:
        """Stream the same trace as :meth:`sample` in bounded-memory
        chunks (see :func:`repro.core.irm.sample_trace_chunks`)."""
        if self.kind == "tenant_churn":
            raise ValueError(
                "tenant_churn workloads are driven round-by-round by the "
                "admission runner; they have no single merged trace"
            )
        if self.kind == "trace":
            P = np.asarray(self.trace_proxies, dtype=np.int32)
            O = np.asarray(self.trace_objects, dtype=np.int64)
            if n_requests > len(P):
                raise ValueError(
                    f"trace has {len(P)} requests, {n_requests} asked"
                )
            for s in range(0, n_requests, chunk_size):
                e = min(s + chunk_size, n_requests)
                yield IRMTrace(P[s:e], O[s:e])
            return
        if self.kind == "serving":
            # re-slice the canonical request batches to chunk_size: the
            # stream is identical to sample() whatever the chunking.
            buf_p: List[np.ndarray] = []
            buf_o: List[np.ndarray] = []
            buffered = 0
            for p, o in iter_event_batches(
                self.serving_layout(), self.alphas, self.proxy_rates,
                n_requests, seed,
            ):
                buf_p.append(p)
                buf_o.append(o)
                buffered += len(p)
                while buffered >= chunk_size:
                    P = np.concatenate(buf_p)
                    O = np.concatenate(buf_o)
                    yield IRMTrace(P[:chunk_size], O[:chunk_size])
                    buf_p, buf_o = [P[chunk_size:]], [O[chunk_size:]]
                    buffered -= chunk_size
            if buffered:
                yield IRMTrace(np.concatenate(buf_p), np.concatenate(buf_o))
            return
        start = 0
        for chunk in sample_trace_chunks(
            self.rates(), n_requests, chunk_size=chunk_size, seed=seed
        ):
            if self.kind == "shot_noise":
                chunk = IRMTrace(
                    chunk.proxies, self._rotate(chunk.objects, start)
                )
            start += len(chunk)
            yield chunk

    def object_lengths(self, seed: int) -> np.ndarray:
        return self.lengths.materialize(self.n_objects, seed)

    # ------------------------------------------------------------------
    def scaled(self, requests: float, catalogue: float) -> "Workload":
        """Scale the catalogue (and phase length, with requests)."""
        kw = {}
        if catalogue != 1.0 and self.kind == "serving":
            # n_objects is derived; the catalogue knob is the prompt pool
            kw["n_prompts"] = max(1, round(self.n_prompts * catalogue))
        elif catalogue != 1.0 and self.kind != "trace":
            if self.lengths.kind == "explicit":
                raise ValueError(
                    "cannot catalogue-scale a workload with explicit "
                    "per-object lengths; resample the length vector at "
                    "the new size instead"
                )
            kw["n_objects"] = max(1, round(self.n_objects * catalogue))
            if self.kind == "shot_noise":
                kw["phase_shift"] = max(1, round(self.phase_shift * catalogue))
        if requests != 1.0 and self.kind == "shot_noise":
            kw["phase_requests"] = max(
                1, round(self.phase_requests * requests)
            )
        if requests != 1.0 and self.kind == "tenant_churn":
            kw["round_requests"] = max(
                1, round(self.round_requests * requests)
            )
        return replace(self, **kw) if kw else self

    def to_dict(self) -> dict:
        d = asdict(self)
        d["lengths"] = asdict(self.lengths)
        return d

    @staticmethod
    def from_dict(d: dict) -> "Workload":
        d = dict(d)
        lengths = d.pop("lengths", None) or {}
        if lengths.get("values") is not None:
            lengths["values"] = tuple(lengths["values"])
        for key in ("alphas", "proxy_rates", "trace_proxies", "trace_objects"):
            if d.get(key) is not None:
                d[key] = tuple(d[key])
        if d.get("tenant_events") is not None:
            d["tenant_events"] = tuple(
                (int(r), str(a), int(t)) for r, a, t in d["tenant_events"]
            )
        return Workload(lengths=LengthSpec(**lengths), **d)
