"""The System and Estimator axes of a :class:`repro.scenario.Scenario`.

:class:`System` declares the cache under test — sharing variant, virtual
allocations, RRE configuration, ghost retention, which execution backend
runs it, and (optionally) an online :class:`AdmissionSpec` that turns
the static per-proxy allocations into SLA targets managed by the
Section IV-C admission controller. :class:`Estimator` declares how hit
probabilities are obtained: Monte-Carlo simulation or the working-set
fixed point of paper Section IV. All three are plain frozen dataclasses
that round-trip through JSON, so an experiment is reproducible from its
artifact alone.
"""

from __future__ import annotations

from dataclasses import asdict, dataclass, replace
from typing import Optional, Tuple

import numpy as np

from repro.core.cluster import EXECUTORS, FaultSpec
from repro.core.fastsim import SimParams
from repro.core.workingset import ATTRIBUTIONS

VARIANTS = ("lru", "slru", "noshare", "pooled")
# "auto" lets fastsim pick (C loop when a compiler exists, else the
# inlined Python loop); "reference" drives the hookable executable-spec
# classes (slow — small runs and debugging only).
BACKENDS = ("auto", "c", "flat", "generic", "xla", "reference")
ESTIMATORS = ("monte_carlo", "working_set")


@dataclass(frozen=True)
class AdmissionSpec:
    """Online admission-control configuration (paper Section IV-C).

    Attaching an ``AdmissionSpec`` to a :class:`System` reinterprets the
    system's ``allocations`` as per-tenant **SLA allocations** ``b*``
    (the memory each tenant was sold, unshared-equivalent) and requires
    an explicit ``physical_capacity`` ``B`` — the point of overbooking
    is ``sum b* > B``. The scenario runner then replays the workload's
    tenant-churn event stream through an
    :class:`~repro.core.admission.AdmissionController`: arrivals are
    admitted or rejected by the conservative eq. (13) test, popularity
    estimates stream in per round, virtual allocations are recomputed
    via the eq. (10) working-set mapping, departures trigger the
    footnote-1 recomputation, and overcommitment evicts the most
    recently admitted tenants.

    Fields
    ------
    attribution:
        Length-attribution model used for the eq. (10) virtual-
        allocation evaluation — one of ``L1`` (exact eq. (5)),
        ``Lstar`` (eq. (14)), ``L2`` (eq. (15)).
    safety_margin:
        Fraction of ``B`` held back from the eq. (13) headroom test
        (``headroom = B * (1 - safety_margin) - committed``); guards the
        estimate-driven refresh against popularity-estimation noise.
    laplace:
        Laplace smoothing added to the per-round popularity estimates
        (see :meth:`repro.core.irm.PopularityEstimator.rates`).
    decay:
        Exponential forgetting factor applied to the popularity counts
        once per round (1.0 = never forget — the stationary-IRM
        default; < 1 tracks non-stationary demand).
    refresh_on_reject:
        When an arrival fails the conservative test, refresh the
        virtual allocations from the current estimates (freeing the
        sharing surplus) and retry the admission once — the paper's
        intended use of the working-set approximation ("to facilitate
        admission control").
    evict_on_overcommit:
        Run :meth:`~repro.core.admission.AdmissionController.enforce`
        after every refresh, evicting most-recently-admitted tenants
        while the total virtual commitment exceeds
        ``B * (1 - safety_margin)`` (only reachable after departures
        make the survivors' allocations regrow).
    """

    attribution: str = "L1"
    safety_margin: float = 0.0
    laplace: float = 0.0
    decay: float = 1.0
    refresh_on_reject: bool = True
    evict_on_overcommit: bool = True

    def __post_init__(self) -> None:
        # "full" is excluded: without a sharing term, eq. (10) returns
        # b = b* exactly and the controller degenerates to static
        # partitioning — never what an admission spec means.
        shared = tuple(a for a in ATTRIBUTIONS if a != "full")
        if self.attribution not in shared:
            raise ValueError(
                f"unknown admission attribution {self.attribution!r}; "
                f"options: {shared}"
            )
        if not 0.0 <= self.safety_margin < 1.0:
            raise ValueError("safety_margin must be in [0, 1)")
        if not 0.0 <= self.decay <= 1.0:
            raise ValueError("decay must be in [0, 1]")
        if self.laplace < 0.0:
            raise ValueError("laplace must be nonnegative")

    def to_dict(self) -> dict:
        return asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "AdmissionSpec":
        return AdmissionSpec(**d)


@dataclass(frozen=True)
class System:
    """Declarative cache-system configuration.

    Fields
    ------
    variant:
        ``lru`` — the paper's flat shared LRU-lists (Section III);
        ``slru`` — memcached-style Segmented LRU under sharing
        (Section VII); ``noshare`` — independent full-length-charging
        LRUs (the Table III baseline); ``pooled`` — one collective LRU
        over the merged demand.
    allocations:
        Per-proxy virtual allocations ``b_i`` (in object-length units).
        With ``admission`` set these are the per-tenant *SLA* targets
        ``b*`` instead, and the runner manages the actual virtual
        allocations online.
    physical_capacity:
        Physical cache size ``B``. Defaults to ``sum(allocations)`` (or
        ``sum(b_hat)`` when slack is configured, so the slack is
        actually backed by memory). Required explicitly when
        ``admission`` is set.
    ghost_retention:
        Keep evicted-from-list objects resident while another list still
        holds them (the paper's ghost semantics).
    slack_frac / ripple_allocations / batch_interval:
        RRE (Section IV-D): ``slack_frac`` > 0 derives ripple thresholds
        ``b_hat = ceil(b * (1 + slack_frac))`` unless an explicit
        ``ripple_allocations`` overrides it; ``batch_interval`` > 0 adds
        delayed batch eviction every that-many set operations.
    hot_frac / warm_frac:
        S-LRU segment split (``variant="slru"`` only).
    backend:
        Execution engine: ``auto`` (C loop when a compiler exists, else
        the inlined Python loop), ``c``, ``flat``, ``generic``, ``xla``,
        or ``reference`` (the hookable executable-spec classes — slow,
        small runs and debugging only).
    admission:
        Optional :class:`AdmissionSpec` enabling the online Section
        IV-C admission-control loop (tenant-churn workloads only).
    nodes:
        Number of MCD-OS nodes behind the consistent-hash ring
        (:mod:`repro.core.cluster`). ``1`` (default) is the paper's
        single-server prototype; ``K > 1`` shards the object space
        across K homogeneous nodes, each a full shared cache with these
        ``allocations``.
    faults:
        Optional :class:`~repro.core.cluster.FaultSpec` fault-injection
        schedule (scheduled + seeded-random ``fail`` / ``recover`` /
        ``add`` / ``remove`` events, failover retry budget, recovery
        windows). Setting it — even empty — routes the run through the
        cluster simulator; per-phase hit rates, remap fractions and
        recovery time land in ``Report.extras["cluster"]``.
    executor:
        How the cluster's per-node feeding pass runs: ``sequential``
        (default — the reference path) or ``parallel`` (a
        :class:`~repro.core.cluster.ClusterExecutor` process pool;
        bit-identical results, one worker process per node subset).
        Setting ``parallel`` routes the run through the cluster
        simulator even at ``nodes=1``.
    workers:
        Process count for ``executor="parallel"`` (default:
        ``os.cpu_count()``, capped at the node count). Never affects
        results — only wall-clock time.
    """

    variant: str = "lru"
    allocations: Tuple[int, ...] = ()
    physical_capacity: Optional[int] = None
    ghost_retention: bool = True
    slack_frac: float = 0.0
    ripple_allocations: Optional[Tuple[int, ...]] = None
    batch_interval: int = 0
    hot_frac: float = 0.32
    warm_frac: float = 0.32
    backend: str = "auto"
    admission: Optional[AdmissionSpec] = None
    nodes: int = 1
    faults: Optional[FaultSpec] = None
    executor: str = "sequential"
    workers: Optional[int] = None

    def __post_init__(self) -> None:
        if self.variant not in VARIANTS:
            raise ValueError(
                f"unknown variant {self.variant!r}; options: {VARIANTS}"
            )
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; options: {BACKENDS}"
            )
        if not self.allocations:
            raise ValueError("system needs per-proxy allocations")
        if self.slack_frac < 0:
            raise ValueError("slack_frac must be nonnegative")
        if self.admission is not None:
            if self.physical_capacity is None:
                raise ValueError(
                    "admission-controlled systems need an explicit "
                    "physical_capacity (allocations are SLA targets; "
                    "overbooking means sum b* > B)"
                )
            if self.variant != "lru":
                raise ValueError(
                    "admission control models the flat shared-LRU "
                    "system (variant='lru')"
                )
        if self.nodes < 1:
            raise ValueError("nodes must be >= 1")
        if self.executor not in EXECUTORS:
            raise ValueError(
                f"unknown executor {self.executor!r}; options: {EXECUTORS}"
            )
        if self.workers is not None:
            if self.executor != "parallel":
                raise ValueError(
                    "workers applies to executor='parallel' only"
                )
            if self.workers < 1:
                raise ValueError("workers must be >= 1")
        if self.is_cluster:
            if self.variant != "lru":
                raise ValueError(
                    "cluster simulation models the flat shared-LRU "
                    "system (variant='lru')"
                )
            if self.backend not in ("auto", "c", "flat"):
                raise ValueError(
                    "cluster systems run on the chunk-fed fastsim "
                    "backends: backend must be 'auto', 'c' or 'flat'"
                )
            if self.admission is not None:
                raise ValueError(
                    "admission control and cluster fault injection "
                    "cannot be combined (one scenario axis at a time)"
                )

    @property
    def is_cluster(self) -> bool:
        """Whether this system runs through the cluster simulator."""
        return (
            self.nodes > 1
            or self.faults is not None
            or self.executor != "sequential"
        )

    @property
    def n_proxies(self) -> int:
        return len(self.allocations)

    def b_hat(self) -> Optional[Tuple[int, ...]]:
        """Effective RRE ripple allocations (None = no slack)."""
        if self.ripple_allocations is not None:
            return tuple(int(x) for x in self.ripple_allocations)
        if self.slack_frac > 0:
            return tuple(
                int(np.ceil(b * (1.0 + self.slack_frac)))
                for b in self.allocations
            )
        return None

    def capacity(self) -> int:
        if self.physical_capacity is not None:
            return int(self.physical_capacity)
        b_hat = self.b_hat()
        return sum(b_hat) if b_hat is not None else sum(self.allocations)

    def to_sim_params(self) -> SimParams:
        return SimParams(
            allocations=tuple(int(x) for x in self.allocations),
            physical_capacity=self.capacity(),
            ghost_retention=self.ghost_retention,
            ripple_allocations=self.b_hat(),
            variant=self.variant,
            hot_frac=self.hot_frac,
            warm_frac=self.warm_frac,
            batch_interval=self.batch_interval,
        )

    def scaled(self, catalogue: float) -> "System":
        if catalogue == 1.0:
            return self
        kw = {
            "allocations": tuple(
                max(1, round(b * catalogue)) for b in self.allocations
            )
        }
        if self.physical_capacity is not None:
            kw["physical_capacity"] = max(
                1, round(self.physical_capacity * catalogue)
            )
        if self.ripple_allocations is not None:
            kw["ripple_allocations"] = tuple(
                max(1, round(b * catalogue)) for b in self.ripple_allocations
            )
        return replace(self, **kw)

    def to_dict(self) -> dict:
        return asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "System":
        d = dict(d)
        for key in ("allocations", "ripple_allocations"):
            if d.get(key) is not None:
                d[key] = tuple(d[key])
        if d.get("admission") is not None:
            d["admission"] = AdmissionSpec.from_dict(d["admission"])
        if d.get("faults") is not None:
            d["faults"] = FaultSpec.from_dict(d["faults"])
        return System(**d)


@dataclass(frozen=True)
class Estimator:
    """How hit probabilities are produced.

    ``monte_carlo`` simulates the system trajectory (exact semantics,
    PASTA residence-time occupancy estimator); ``working_set`` solves the
    paper's eq. (8) fixed point under the selected length-attribution
    model — no trace, milliseconds instead of minutes, approximate.

    Fields
    ------
    kind:
        ``monte_carlo`` or ``working_set``.
    attribution:
        Working-set length-attribution model: ``L1`` (exact eq. (5)
        expectation), ``Lstar`` (eq. (14) Jensen bound), ``L2``
        (eq. (15)), or ``full`` (classical Denning-Schwartz, no
        sharing). Ignored by ``monte_carlo``.
    n_quad:
        Gauss-Legendre nodes for the exact L1 expectation (default
        ``max(8, ceil((J+1)/2))`` — exact for the degree-(J-1)
        polynomial integrand).
    n_outer / n_bisect / damping / tol:
        Fixed-point solver knobs: damped-Jacobi outer iterations, inner
        bisection steps per proxy, damping factor, and relative
        convergence tolerance on the characteristic times.
    streaming:
        Monte-Carlo memory mode. ``True`` feeds the trace through the
        engine in ``chunk_size`` pieces (``Workload.iter_chunks`` ->
        ``fastsim.simulate_chunks``) and reports occupancy as a sparse
        touched-set, so peak memory is O(chunk + engine state) instead
        of O(n_requests + J*N); ``False`` forces the one-shot dense
        path; ``None`` (default) picks streaming automatically once
        ``n_requests * J >= 12M`` or ``J * n_objects >= 4M`` (the
        runner's ``STREAMING_REQUEST_CELLS`` / ``STREAMING_STATE_CELLS``
        thresholds — the Section VI-C full-catalogue regime). Results
        are bit-identical either way — streaming only changes the
        memory footprint and the occupancy representation.
    chunk_size:
        Requests per streamed chunk (streaming mode only).
    replications:
        Monte-Carlo only: number of independent ensemble replicas R.
        ``1`` (default) runs the classic single trajectory. ``R > 1``
        runs R replicas on independent trace substreams (replica 0 uses
        the scenario's own trace seed, so its results are bit-identical
        to a ``replications=1`` run) and the Report aggregates them —
        ``hit_prob`` / ``hit_rate`` become cross-replica means and the
        per-replica estimates land in ``Report.ensemble``, enabling the
        ``hit_prob_ci()`` / ``hit_rate_ci()`` confidence-band
        accessors. On ``backend="xla"`` all replicas run batched inside
        one compiled program (:func:`repro.core.fastsim_jax.
        simulate_ensemble`); other backends run them sequentially with
        identical per-replica results.
    """

    kind: str = "monte_carlo"
    attribution: str = "L1"  # working_set only
    n_quad: Optional[int] = None
    n_outer: int = 200
    n_bisect: int = 90
    damping: float = 0.7
    tol: float = 1e-7
    streaming: Optional[bool] = None  # monte_carlo only; None = auto by size
    chunk_size: int = 250_000  # requests per streamed chunk
    replications: int = 1  # monte_carlo only; R > 1 = ensemble run

    def __post_init__(self) -> None:
        if self.kind not in ESTIMATORS:
            raise ValueError(
                f"unknown estimator {self.kind!r}; options: {ESTIMATORS}"
            )
        if self.attribution not in ATTRIBUTIONS:
            raise ValueError(
                f"unknown attribution {self.attribution!r}; "
                f"options: {ATTRIBUTIONS}"
            )
        if self.chunk_size < 1:
            raise ValueError("chunk_size must be positive")
        if self.replications < 1:
            raise ValueError("replications must be >= 1")
        if self.replications > 1 and self.kind != "monte_carlo":
            raise ValueError(
                "replications apply to the monte_carlo estimator only "
                "(working_set is deterministic)"
            )

    def to_dict(self) -> dict:
        return asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "Estimator":
        return Estimator(**d)
