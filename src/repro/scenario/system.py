"""The System and Estimator axes of a :class:`repro.scenario.Scenario`.

:class:`System` declares the cache under test — sharing variant, virtual
allocations, RRE configuration, ghost retention, and which execution
backend runs it. :class:`Estimator` declares how hit probabilities are
obtained: Monte-Carlo simulation or the working-set fixed point of paper
Section IV. Both are plain frozen dataclasses that round-trip through
JSON, so an experiment is reproducible from its artifact alone.
"""

from __future__ import annotations

from dataclasses import asdict, dataclass, replace
from typing import Optional, Tuple

import numpy as np

from repro.core.fastsim import SimParams
from repro.core.workingset import ATTRIBUTIONS

VARIANTS = ("lru", "slru", "noshare", "pooled")
# "auto" lets fastsim pick (C loop when a compiler exists, else the
# inlined Python loop); "reference" drives the hookable executable-spec
# classes (slow — small runs and debugging only).
BACKENDS = ("auto", "c", "flat", "generic", "xla", "reference")
ESTIMATORS = ("monte_carlo", "working_set")


@dataclass(frozen=True)
class System:
    """Declarative cache-system configuration.

    ``slack_frac`` > 0 derives RRE ripple allocations
    ``b_hat = ceil(b * (1 + slack_frac))`` (paper Section IV-D) unless an
    explicit ``ripple_allocations`` overrides it; ``batch_interval`` adds
    the delayed-batch-eviction mechanism. ``physical_capacity`` defaults
    to ``sum(allocations)`` (or ``sum(b_hat)`` when slack is configured,
    so the slack is actually backed by memory).
    """

    variant: str = "lru"
    allocations: Tuple[int, ...] = ()
    physical_capacity: Optional[int] = None
    ghost_retention: bool = True
    slack_frac: float = 0.0
    ripple_allocations: Optional[Tuple[int, ...]] = None
    batch_interval: int = 0
    hot_frac: float = 0.32
    warm_frac: float = 0.32
    backend: str = "auto"

    def __post_init__(self) -> None:
        if self.variant not in VARIANTS:
            raise ValueError(
                f"unknown variant {self.variant!r}; options: {VARIANTS}"
            )
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; options: {BACKENDS}"
            )
        if not self.allocations:
            raise ValueError("system needs per-proxy allocations")
        if self.slack_frac < 0:
            raise ValueError("slack_frac must be nonnegative")

    @property
    def n_proxies(self) -> int:
        return len(self.allocations)

    def b_hat(self) -> Optional[Tuple[int, ...]]:
        """Effective RRE ripple allocations (None = no slack)."""
        if self.ripple_allocations is not None:
            return tuple(int(x) for x in self.ripple_allocations)
        if self.slack_frac > 0:
            return tuple(
                int(np.ceil(b * (1.0 + self.slack_frac)))
                for b in self.allocations
            )
        return None

    def capacity(self) -> int:
        if self.physical_capacity is not None:
            return int(self.physical_capacity)
        b_hat = self.b_hat()
        return sum(b_hat) if b_hat is not None else sum(self.allocations)

    def to_sim_params(self) -> SimParams:
        return SimParams(
            allocations=tuple(int(x) for x in self.allocations),
            physical_capacity=self.capacity(),
            ghost_retention=self.ghost_retention,
            ripple_allocations=self.b_hat(),
            variant=self.variant,
            hot_frac=self.hot_frac,
            warm_frac=self.warm_frac,
            batch_interval=self.batch_interval,
        )

    def scaled(self, catalogue: float) -> "System":
        if catalogue == 1.0:
            return self
        kw = {
            "allocations": tuple(
                max(1, round(b * catalogue)) for b in self.allocations
            )
        }
        if self.physical_capacity is not None:
            kw["physical_capacity"] = max(
                1, round(self.physical_capacity * catalogue)
            )
        if self.ripple_allocations is not None:
            kw["ripple_allocations"] = tuple(
                max(1, round(b * catalogue)) for b in self.ripple_allocations
            )
        return replace(self, **kw)

    def to_dict(self) -> dict:
        return asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "System":
        d = dict(d)
        for key in ("allocations", "ripple_allocations"):
            if d.get(key) is not None:
                d[key] = tuple(d[key])
        return System(**d)


@dataclass(frozen=True)
class Estimator:
    """How hit probabilities are produced.

    ``monte_carlo`` simulates the system trajectory (exact semantics,
    PASTA residence-time occupancy estimator); ``working_set`` solves the
    paper's eq. (8) fixed point under the selected length-attribution
    model — no trace, milliseconds instead of minutes, approximate.

    ``streaming`` controls the Monte-Carlo memory mode: ``True`` feeds
    the trace through the engine in ``chunk_size`` pieces
    (``Workload.iter_chunks`` -> ``fastsim.simulate_chunks``) and
    reports occupancy as a sparse touched-set, so peak memory is
    O(chunk + engine state) instead of O(n_requests + J*N); ``False``
    forces the one-shot dense path; ``None`` (default) picks streaming
    automatically once ``n_requests * J`` or ``J * n_objects`` crosses
    the runner's thresholds (the Section VI-C full-catalogue regime).
    Results are bit-identical either way — streaming only changes the
    memory footprint and the occupancy representation.
    """

    kind: str = "monte_carlo"
    attribution: str = "L1"  # working_set only
    n_quad: Optional[int] = None
    n_outer: int = 200
    n_bisect: int = 90
    damping: float = 0.7
    tol: float = 1e-7
    streaming: Optional[bool] = None  # monte_carlo only; None = auto by size
    chunk_size: int = 250_000  # requests per streamed chunk

    def __post_init__(self) -> None:
        if self.kind not in ESTIMATORS:
            raise ValueError(
                f"unknown estimator {self.kind!r}; options: {ESTIMATORS}"
            )
        if self.attribution not in ATTRIBUTIONS:
            raise ValueError(
                f"unknown attribution {self.attribution!r}; "
                f"options: {ATTRIBUTIONS}"
            )
        if self.chunk_size < 1:
            raise ValueError("chunk_size must be positive")

    def to_dict(self) -> dict:
        return asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "Estimator":
        return Estimator(**d)
