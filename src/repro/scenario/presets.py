"""Named scenario presets for every paper experiment.

Each preset is a factory returning a :class:`Scenario` at **paper
scale** (the sizes of the source paper's tables/figures); callers dial
them down with :meth:`Scenario.scaled` — that is how the benchmark
harness maps its ``--quick`` / default / ``REPRO_FULL`` fidelity modes
onto one definition instead of per-benchmark env-var forks.

    from repro.scenario import get_preset, list_presets

    sc = get_preset("table1", b=(8, 8, 64)).scaled(requests=0.15)
    report = sc.run()
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

from repro.core.cluster import FaultSpec

from .scenario import Scenario
from .system import AdmissionSpec, Estimator, System
from .workload import Workload

# The paper's Section V setup (Tables I-III): J=3 lists over a B=1000
# physical cache, unit objects, Zipf alphas (0.75, 0.5, 1.0), N=1000
# (calibrated against Table II), 10M requests per combo at full scale.
SECTION5_ALPHAS = (0.75, 0.5, 1.0)
SECTION5_N = 1000
SECTION5_B = 1000
SECTION5_REQUESTS = 10_000_000

# Section VI-C workload (Fig. 2 / Table V): J=9 proxies, Zipf
# 0.5+0.5(i-1), 1e6 items of 100 kB (1 unit), B=3 GB, allocations
# 3x100 MB + 3x200 MB + 3x700 MB in 100 kB units, 3M requests.
FIG2_ALPHAS = tuple(0.5 + 0.5 * i for i in range(9))
FIG2_B_UNITS = (1000, 1000, 1000, 2000, 2000, 2000, 7000, 7000, 7000)
FIG2_N = 1_000_000
FIG2_REQUESTS = 3_000_000


def _section5_workload() -> Workload:
    return Workload(kind="irm", n_objects=SECTION5_N, alphas=SECTION5_ALPHAS)


def table1(b: Tuple[int, int, int] = (64, 64, 64), seed: int = 7) -> Scenario:
    return Scenario(
        name="table1",
        description=(
            "Paper Table I: empirical per-object hit probabilities of the "
            f"shared J=3 cache at b={tuple(b)} (Monte-Carlo, PASTA "
            "occupancy estimator)."
        ),
        workload=_section5_workload(),
        system=System(
            variant="lru",
            allocations=tuple(b),
            physical_capacity=SECTION5_B,
        ),
        estimator=Estimator("monte_carlo"),
        n_requests=SECTION5_REQUESTS,
        seed=seed,
    )


def table3_noshare(
    b: Tuple[int, int, int] = (64, 64, 8), seed: int = 11
) -> Scenario:
    return Scenario(
        name="table3_noshare",
        description=(
            "Paper Table III: the not-shared baseline — J independent "
            f"full-length-charging LRUs at b={tuple(b)}."
        ),
        workload=_section5_workload(),
        system=System(variant="noshare", allocations=tuple(b)),
        estimator=Estimator("monte_carlo"),
        n_requests=SECTION5_REQUESTS,
        seed=seed,
    )


def fig2_ripple(seed: int = 23) -> Scenario:
    return Scenario(
        name="fig2_ripple",
        description=(
            "Paper Fig. 2 (Section VI-C): evictions-per-set histogram of "
            "the J=9 heterogeneous-Zipf workload (1e6 objects, 3 GB "
            "cache in 100 kB units)."
        ),
        workload=Workload(kind="irm", n_objects=FIG2_N, alphas=FIG2_ALPHAS),
        system=System(
            variant="lru",
            allocations=FIG2_B_UNITS,
            physical_capacity=sum(FIG2_B_UNITS),
        ),
        estimator=Estimator("monte_carlo"),
        n_requests=FIG2_REQUESTS,
        warmup=FIG2_REQUESTS // 10,
        seed=seed,
    )


def rre(slack_frac: float = 0.25, batch_interval: int = 0, seed: int = 31) -> Scenario:
    n = FIG2_REQUESTS // 3
    return Scenario(
        name="rre",
        description=(
            "Section IV-D Reducing Ripple Evictions: the Fig.-2 system "
            f"with slack thresholds (slack={slack_frac}) and delayed "
            f"batch evictions (interval={batch_interval})."
        ),
        workload=Workload(kind="irm", n_objects=FIG2_N, alphas=FIG2_ALPHAS),
        system=System(
            variant="lru",
            allocations=FIG2_B_UNITS,
            slack_frac=slack_frac,
            batch_interval=batch_interval,
        ),
        estimator=Estimator("monte_carlo"),
        n_requests=n,
        warmup=n // 10,
        ripple_from=0,
        seed=seed,
    )


def slru(b: Tuple[int, int, int] = (64, 64, 64), seed: int = 13) -> Scenario:
    return Scenario(
        name="slru",
        description=(
            "Section VII: memcached Segmented-LRU (HOT/WARM/COLD) under "
            f"object sharing at b={tuple(b)} — compare against the "
            "'table1' flat-LRU preset on the same seed."
        ),
        workload=_section5_workload(),
        system=System(
            variant="slru",
            allocations=tuple(b),
            physical_capacity=SECTION5_B,
        ),
        estimator=Estimator("monte_carlo"),
        n_requests=SECTION5_REQUESTS,
        seed=seed,
    )


def j2_bounds(seed: int = 5) -> Scenario:
    return Scenario(
        name="j2_bounds",
        description=(
            "Section V J=2 discussion: simulate alphas (0.75, 1.0) at "
            "b=(32, 32); solving the same scenario with "
            "with_estimator('working_set', attribution=...) under "
            "L1/Lstar/L2 brackets the truth."
        ),
        workload=Workload(
            kind="irm", n_objects=SECTION5_N, alphas=(0.75, 1.0)
        ),
        system=System(
            variant="lru",
            allocations=(32, 32),
            physical_capacity=SECTION5_N,
        ),
        estimator=Estimator("monte_carlo"),
        n_requests=SECTION5_REQUESTS,
        seed=seed,
    )


def shot_noise(seed: int = 41) -> Scenario:
    n = SECTION5_REQUESTS
    return Scenario(
        name="shot_noise",
        description=(
            "Non-stationary catalogue churn (shot-noise style, cf. Olmos "
            "et al.): the Section-V system under per-phase popularity "
            "rotation — the estimator-vs-simulator comparison off the "
            "stationary IRM."
        ),
        workload=Workload(
            kind="shot_noise",
            n_objects=SECTION5_N,
            alphas=SECTION5_ALPHAS,
            phase_requests=n // 20,
            phase_shift=50,
        ),
        system=System(
            variant="lru",
            allocations=(64, 64, 64),
            physical_capacity=SECTION5_B,
        ),
        estimator=Estimator("monte_carlo"),
        n_requests=n,
        seed=seed,
    )


def admission_overbooking(
    b_star: int = 64, n_tenants: int = 8, seed: int = 47
) -> Scenario:
    """Section IV-C as an online episode.

    ``n_tenants`` similar-but-not-identical Zipf tenants (high demand
    overlap — the regime sharing targets) ask for ``b* = 64`` each
    against a physical cache sized for only six unshared tenants
    (``B = 384``): tenants 0-5 arrive one per round, tenant 2 departs,
    then tenants 6-7 arrive into the freed + overbooked headroom. The
    runner validates the final admitted set by simulating it at its
    virtual allocations and comparing per-tenant hit rates against the
    unshared eq. (10) SLA prediction.
    """
    alphas = tuple(0.9 + 0.02 * i for i in range(n_tenants))
    if n_tenants == 8:
        events = tuple((r, "arrive", r) for r in range(6)) + (
            (6, "depart", 2),
            (7, "arrive", 6),
            (8, "arrive", 7),
        )
        churn = "with arrivals, one departure, "
    else:
        # generic fallback: one arrival per round, no churn tail
        events = tuple((r, "arrive", r) for r in range(n_tenants))
        churn = "with one arrival per round, "
    return Scenario(
        name="admission_overbooking",
        description=(
            "Paper Section IV-C online: admission control + overbooking "
            f"episode — {n_tenants} tenants at b*={b_star} against "
            f"B={6 * b_star} (room for 6 unshared), {churn}"
            "eq. (13) admissions, eq. (10) virtual-allocation "
            "refreshes, and a final realized-vs-predicted SLA check."
        ),
        workload=Workload(
            kind="tenant_churn",
            n_objects=SECTION5_N,
            alphas=alphas,
            tenant_events=events,
            round_requests=200_000,
        ),
        system=System(
            variant="lru",
            allocations=(b_star,) * n_tenants,
            physical_capacity=6 * b_star,
            admission=AdmissionSpec(),
        ),
        estimator=Estimator("monte_carlo"),
        n_requests=2_000_000,
        seed=seed,
    )


def serving_multitenant(
    n_tenants: int = 6, shared_frac: float = 0.75, seed: int = 61
) -> Scenario:
    """Multi-tenant KV prefix-cache serving with gated onboarding.

    ``n_tenants`` tenants share a paged KV-block store sized for only
    four dedicated tenants (``B = 4 b*`` against ``sum b* = 6 b*``):
    each serves Zipf traffic over 512 prompts whose system-prompt /
    few-shot prefixes (16 blocks) are drawn from a
    ``shared_frac``-shared pool, followed by 2 user-suffix blocks from
    4 per-prompt variants. Onboarding runs through the eq. (13) test on
    the declared rates — later tenants are admitted into the sharing
    surplus the earlier ones free up — and the admitted set drives a
    10M-block-event trace through the fastsim engine. Blocks are priced
    with the qwen3-1.7b paged-KV layout (16 tokens/block);
    ``Report.extras["serving"]`` carries the hit/FLOPs/bytes-shared
    economics and the onboarding record.
    """
    b_star = 2048
    return Scenario(
        name="serving_multitenant",
        description=(
            "Multi-tenant KV prefix-cache serving: "
            f"{n_tenants} Zipf tenants x 512 prompts, "
            f"{shared_frac:.0%}-shared 16-block prefixes + 2-block "
            f"suffix tails, b*={b_star} blocks each against "
            f"B={4 * b_star} (room for 4 unshared) with eq. (13) "
            "admission-gated onboarding; blocks priced via the "
            "qwen3-1.7b paged-KV layout."
        ),
        workload=Workload(
            kind="serving",
            alphas=tuple(0.8 + 0.05 * i for i in range(n_tenants)),
            proxy_rates=tuple(1.0 + 0.25 * i for i in range(n_tenants)),
            n_prompts=512,
            shared_frac=shared_frac,
            prefix_blocks=16,
            suffix_blocks=2,
            suffix_choices=4,
            kv_arch="qwen3-1.7b",
            block_tokens=16,
        ),
        system=System(
            variant="lru",
            allocations=(b_star,) * n_tenants,
            physical_capacity=4 * b_star,
            admission=AdmissionSpec(),
        ),
        estimator=Estimator("monte_carlo"),
        n_requests=10_000_000,
        seed=seed,
    )


def cluster_failover(nodes: int = 4, seed: int = 53) -> Scenario:
    """Fault-tolerant cluster scenario: kill-and-recover one of K nodes.

    The Fig.-2 workload (J=9 heterogeneous Zipf proxies over 1e6
    objects) sharded across ``nodes`` homogeneous MCD-OS nodes behind a
    64-vnode consistent-hash ring. Node 1 fails at 40% of the trace and
    recovers warm at 60%; in between, the failover client walks the
    ring (budget 2) and exhausted requests degrade to misses. The
    per-phase hit rates, remap fractions, retry counts, and recovery
    time-to-baseline land in ``Report.extras["cluster"]``.
    """
    return Scenario(
        name="cluster_failover",
        description=(
            f"Fault-tolerant MCD-OS cluster: the Fig.-2 workload across "
            f"K={nodes} nodes behind a consistent-hash ring; node 1 "
            "fails at 40% of the trace, recovers warm at 60% — "
            "failover routing, graceful degradation, and "
            "recovery-to-baseline telemetry."
        ),
        workload=Workload(kind="irm", n_objects=FIG2_N, alphas=FIG2_ALPHAS),
        system=System(
            variant="lru",
            allocations=FIG2_B_UNITS,
            physical_capacity=sum(FIG2_B_UNITS),
            nodes=nodes,
            faults=FaultSpec(
                events=((0.4, "fail", 1), (0.6, "recover", 1)),
            ),
        ),
        estimator=Estimator("monte_carlo"),
        n_requests=FIG2_REQUESTS,
        warmup=FIG2_REQUESTS // 10,
        seed=seed,
    )


def quickstart(seed: int = 1) -> Scenario:
    return Scenario(
        name="quickstart",
        description=(
            "Small Section-V demo (400k requests at b=(64, 64, 8)) used "
            "by examples/quickstart.py."
        ),
        workload=_section5_workload(),
        system=System(
            variant="lru",
            allocations=(64, 64, 8),
            physical_capacity=SECTION5_B,
        ),
        estimator=Estimator("monte_carlo"),
        n_requests=400_000,
        seed=seed,
    )


# Table II is the working-set view of the Table-I system; expressing it
# via with_estimator keeps the two presets structurally identical.
def _table2_ws(
    b: Tuple[int, int, int] = (64, 64, 64), attribution: str = "L1"
) -> Scenario:
    sc = table1(b).with_estimator("working_set", attribution=attribution)
    return dataclasses.replace(
        sc,
        name="table2_ws",
        description=(
            "Paper Table II: working-set approximation (eq. (8) with "
            f"{attribution} attribution) of the Table-I system at "
            f"b={tuple(b)}."
        ),
    )


PRESETS: Dict[str, Callable[..., Scenario]] = {
    "table1": table1,
    "table2_ws": _table2_ws,
    "table3_noshare": table3_noshare,
    "fig2_ripple": fig2_ripple,
    "rre": rre,
    "slru": slru,
    "j2_bounds": j2_bounds,
    "shot_noise": shot_noise,
    "admission_overbooking": admission_overbooking,
    "serving_multitenant": serving_multitenant,
    "cluster_failover": cluster_failover,
    "quickstart": quickstart,
}


def list_presets() -> Dict[str, str]:
    """{name: one-line description} for every registered preset."""
    return {name: fn().description for name, fn in PRESETS.items()}


def get_preset(name: str, **kwargs) -> Scenario:
    """Instantiate a named preset at paper scale.

    Keyword arguments are forwarded to the preset factory (e.g.
    ``get_preset("table1", b=(8, 8, 64))``). Scale down with
    :meth:`Scenario.scaled`.
    """
    try:
        fn = PRESETS[name]
    except KeyError:
        raise KeyError(
            f"unknown preset {name!r}; available: {', '.join(sorted(PRESETS))}"
        ) from None
    return fn(**kwargs)
