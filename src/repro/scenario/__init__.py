"""``repro.scenario`` — one declarative entry point over workloads,
systems, and estimators.

The paper's pipeline is "pick a workload -> pick a sharing policy ->
estimate hit probabilities (Monte-Carlo or working-set) -> feed
admission control". This package turns that into a single serializable
object::

    from repro.scenario import get_preset

    sc = get_preset("table1", b=(64, 64, 8)).scaled(requests=0.1)
    sim = sc.run()                                   # Monte-Carlo Report
    ws = sc.with_estimator("working_set").run()      # same Report type

Axes
----
* :class:`Workload` — stationary IRM/Zipf (per-proxy heterogeneous
  alphas), shot-noise/non-stationary popularity churn, explicit trace
  replay, a ``tenant_churn`` admission episode, or a ``serving``
  multi-tenant KV prefix-cache scenario; object-size distributions via
  :class:`LengthSpec`.
* :class:`System` — flat shared LRU, S-LRU, not-shared, pooled; ghost
  retention, RRE slack/batch config; backend selection across the
  reference ``SharedLRUCache`` and the fastsim Python/C/XLA drivers;
  optional online admission control via :class:`AdmissionSpec`; and
  K-node consistent-hash cluster simulation with seeded fault
  injection via ``System(nodes=K, faults=FaultSpec(...))`` —
  per-phase hit rates, remap fractions, retry counts, and recovery
  time-to-baseline land in ``Report.extras["cluster"]``.
* :class:`Estimator` — ``monte_carlo`` vs ``working_set`` (L1 / Lstar /
  L2 / full attribution), both returning one :class:`Report`. Large
  Monte-Carlo runs stream automatically (chunk-fed engine + sparse
  touched-set occupancy) past the runner's size thresholds
  (``n_requests * J >= 12M`` or ``J * n_objects >= 4M``); results are
  bit-identical to the one-shot dense path. ``replications=R`` turns
  any Monte-Carlo run into an R-replica ensemble (replica 0
  bit-identical to the single run; batched in one compiled program on
  ``backend="xla"``) whose Report carries cross-replica means plus the
  ``hit_prob_ci()`` / ``hit_rate_ci()`` confidence-band accessors.

Admission control (Section IV-C)
--------------------------------
An admission scenario is declarative like everything else — a
``tenant_churn`` workload (tenants + arrival/departure events +
estimation traffic per round) over a ``System`` whose ``allocations``
are the per-tenant SLA targets ``b*`` and whose ``admission`` spec
drives the online controller::

    from repro.scenario import (
        AdmissionSpec, Estimator, Scenario, System, Workload,
    )

    sc = Scenario(
        name="overbook",
        workload=Workload(
            kind="tenant_churn",
            n_objects=1000,
            alphas=(0.9, 0.92, 0.94, 0.96),       # one per tenant
            tenant_events=(
                (0, "arrive", 0), (1, "arrive", 1),
                (2, "arrive", 2), (3, "depart", 0),
                (4, "arrive", 3),
            ),
            round_requests=50_000,                 # estimation traffic
        ),
        system=System(
            allocations=(64, 64, 64, 64),          # SLA targets b*
            physical_capacity=192,                 # B < sum b*: overbook
            admission=AdmissionSpec(attribution="L1"),
        ),
        estimator=Estimator("monte_carlo"),        # validation estimator
        n_requests=500_000,                        # validation trace
        seed=7,
    )
    rep = sc.run()
    rep.extras["admission"]["decisions"]           # admit/reject/... log
    rep.extras["admission"]["overbooking_gain"]    # sum b* / sum b
    rep.extras["admission"]["realized_hit_rate"]   # vs predicted_sla_hit_rate

The episode replays arrivals/departures through the eq. (13) test,
refreshes eq. (10) virtual allocations from online popularity
estimates, and finally *validates* the admitted set by running it at
its virtual allocations with the configured estimator — the returned
:class:`Report` is that validation run, with the full episode under
``extras["admission"]``. The ``admission_overbooking`` preset packages
the paper-scale version.

Serving workloads (KV prefix caching)
-------------------------------------
``Workload(kind="serving")`` declares a multi-tenant LLM-serving
prompt-stream model — per-tenant Zipf popularity over a prompt
catalogue whose system-prompt/few-shot prefixes come from a partially
shared pool, plus per-prompt user-suffix variants — and compiles it to
a (tenant, KV-block) trace: every block-aligned prefix extension is one
chained-key object, so prefix-block residency runs through the same
fastsim engines as every other workload (millions of requests/s)
instead of the per-call reference ``SharedPrefixCache``::

    sc = get_preset("serving_multitenant").scaled(requests=0.1)
    rep = sc.run()
    rep.serving["prefix_hit_token_ratio"]   # tokens served from cache
    rep.serving["prefill_flops_saved"]      # priced via kv_arch
    rep.serving["admission"]                # gated onboarding record

The trace compiler is proven block-for-block equivalent to driving
``SharedPrefixCache.lookup/insert`` per request
(``tests/test_serving_trace.py``); :class:`ServingReport` documents
every derived metric. With ``System(admission=AdmissionSpec())``,
tenant onboarding is gated by the eq. (13) predicted-SLA test before
the trace runs.

Named presets cover every paper experiment (``list_presets()``); the
older entry points (``SimParams``/``simulate_trace``,
``solve_workingset``, ``MCDOSServer.run_trace``) remain supported as the
low-level layer this package drives.
"""

from repro.core.cluster import FaultSpec  # noqa: F401

from .report import Report, ServingReport  # noqa: F401
from .scenario import Scenario  # noqa: F401
from .system import AdmissionSpec, Estimator, System  # noqa: F401
from .workload import LengthSpec, Workload  # noqa: F401
from .presets import PRESETS, get_preset, list_presets  # noqa: F401

__all__ = [
    "AdmissionSpec",
    "Estimator",
    "FaultSpec",
    "LengthSpec",
    "PRESETS",
    "Report",
    "Scenario",
    "ServingReport",
    "System",
    "Workload",
    "get_preset",
    "list_presets",
]
