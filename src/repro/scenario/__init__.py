"""``repro.scenario`` — one declarative entry point over workloads,
systems, and estimators.

The paper's pipeline is "pick a workload -> pick a sharing policy ->
estimate hit probabilities (Monte-Carlo or working-set) -> feed
admission control". This package turns that into a single serializable
object::

    from repro.scenario import get_preset

    sc = get_preset("table1", b=(64, 64, 8)).scaled(requests=0.1)
    sim = sc.run()                                   # Monte-Carlo Report
    ws = sc.with_estimator("working_set").run()      # same Report type

Axes
----
* :class:`Workload` — stationary IRM/Zipf (per-proxy heterogeneous
  alphas), shot-noise/non-stationary popularity churn, explicit trace
  replay; object-size distributions via :class:`LengthSpec`.
* :class:`System` — flat shared LRU, S-LRU, not-shared, pooled; ghost
  retention, RRE slack/batch config; backend selection across the
  reference ``SharedLRUCache`` and the fastsim Python/C/XLA drivers.
* :class:`Estimator` — ``monte_carlo`` vs ``working_set`` (L1 / Lstar /
  L2 / full attribution), both returning one :class:`Report`.

Named presets cover every paper experiment (``list_presets()``); the
older entry points (``SimParams``/``simulate_trace``,
``solve_workingset``, ``MCDOSServer.run_trace``) remain supported as the
low-level layer this package drives.
"""

from .report import Report  # noqa: F401
from .scenario import Scenario  # noqa: F401
from .system import Estimator, System  # noqa: F401
from .workload import LengthSpec, Workload  # noqa: F401
from .presets import PRESETS, get_preset, list_presets  # noqa: F401

__all__ = [
    "Estimator",
    "LengthSpec",
    "PRESETS",
    "Report",
    "Scenario",
    "System",
    "Workload",
    "get_preset",
    "list_presets",
]
