"""Deterministic, checkpointable data pipelines.

* :class:`SyntheticLMData` — seeded synthetic token stream with Zipf
  unigram statistics and injected n-gram structure (so a trained model
  has something learnable and loss decreases measurably).
* :class:`FileTokenData` — memory-mapped binary token file (uint16/32),
  sharded by host, sequential with deterministic shuffle windows.

Both expose ``state()`` / ``restore(state)`` so a resumed training run
continues on the exact batch it would have seen (fault-tolerance tests
assert this), and ``shard(host_id, n_hosts)`` for multi-host use.
"""

from __future__ import annotations

from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


class SyntheticLMData:
    def __init__(
        self,
        vocab_size: int,
        seq_len: int,
        batch_size: int,
        *,
        seed: int = 0,
        zipf_alpha: float = 1.1,
        ngram_boost: int = 64,
        host_id: int = 0,
        n_hosts: int = 1,
    ) -> None:
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.seed = seed
        self.host_id = host_id
        self.n_hosts = n_hosts
        self._step = 0
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        p = ranks ** (-zipf_alpha)
        self._p = p / p.sum()
        # deterministic "grammar": token t is often followed by succ[t]
        rng = np.random.default_rng(seed + 1234)
        self._succ = rng.integers(0, vocab_size, size=vocab_size)
        self._boost = ngram_boost

    def _batch_rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.seed, step, self.host_id, 0xDA7A)
        )

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = self._batch_rng(self._step)
        self._step += 1
        B, T = self.batch_size, self.seq_len
        toks = rng.choice(self.vocab_size, size=(B, T + 1), p=self._p)
        # inject learnable bigram structure: with prob .5 follow succ[t]
        follow = rng.random((B, T)) < 0.5
        toks[:, 1:][follow] = self._succ[toks[:, :-1][follow]]
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
            "mask": np.ones((B, T), bool),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()

    # -- checkpointable state -------------------------------------------
    def state(self) -> Dict[str, int]:
        return {"step": self._step, "seed": self.seed}

    def restore(self, state: Dict[str, int]) -> None:
        assert state["seed"] == self.seed, "data seed changed across restore"
        self._step = int(state["step"])


class FileTokenData:
    """Sequential batches from a flat binary token file (np.memmap)."""

    def __init__(
        self,
        path: str | Path,
        vocab_size: int,
        seq_len: int,
        batch_size: int,
        *,
        dtype=np.uint16,
        host_id: int = 0,
        n_hosts: int = 1,
    ) -> None:
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.host_id = host_id
        self.n_hosts = n_hosts
        self._cursor = host_id * batch_size * seq_len
        n_needed = batch_size * (seq_len + 1)
        if len(self.tokens) < n_needed * n_hosts:
            raise ValueError("token file too small for one global batch")

    def next_batch(self) -> Dict[str, np.ndarray]:
        B, T = self.batch_size, self.seq_len
        span = B * (T + 1)
        stride = span * self.n_hosts
        if self._cursor + span > len(self.tokens):
            self._cursor = self.host_id * span  # wrap epoch
        chunk = np.asarray(
            self.tokens[self._cursor : self._cursor + span], dtype=np.int32
        ).reshape(B, T + 1)
        self._cursor += stride
        chunk = chunk % self.vocab_size
        return {
            "tokens": chunk[:, :-1],
            "labels": chunk[:, 1:],
            "mask": np.ones((B, T), bool),
        }

    def state(self) -> Dict[str, int]:
        return {"cursor": int(self._cursor)}

    def restore(self, state: Dict[str, int]) -> None:
        self._cursor = int(state["cursor"])


def make_pipeline(kind: str, **kw):
    if kind == "synthetic":
        return SyntheticLMData(**kw)
    if kind == "file":
        return FileTokenData(**kw)
    raise ValueError(f"unknown pipeline kind {kind!r}")
