from .pipeline import SyntheticLMData, FileTokenData, make_pipeline  # noqa: F401
