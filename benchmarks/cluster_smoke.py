"""Cluster failover smoke: prove graceful degradation + recovery in CI.

Runs the ``cluster_failover`` preset at smoke scale (fixed seed, fixed
fault schedule: node 1 fails at 40% of the trace and warm-recovers at
60%) plus a fault-free *counterfactual* of the identical trace, and
enforces three hard assertions:

* the outage has a *visible cost* — the faulted run serves strictly
  fewer list hits than the fault-free run of the same trace, and its
  mean hit rate over the outage windows is below the counterfactual's
  over the same windows (a same-trace comparison, so the cache-warming
  trend cannot mask the outage the way a pre-vs-during comparison can);
* the cluster *recovers* — the post-recovery hit rate returns to within
  ``RECOVERY_TOL`` of the pre-fault baseline (the warm restart keeps
  the failed node's cache, so the recovery window is short);
* the run is *deterministic* — a second run under the same seed
  reproduces every estimate bit for bit (the fault engine, ring, and
  failover client add no hidden entropy);
* the *parallel executor is exact* — the same K=16 trace through
  ``executor="parallel"`` (8 workers) reproduces the sequential
  reference bit for bit, estimates and telemetry both, and on hosts
  with at least ``SPEEDUP_MIN_CORES`` visible cores it also clears a
  loose wall-clock speedup floor (the floor is skipped — bit-identity
  is not — on smaller containers, where W forked workers sharing one
  core can only lose).

Used by the CI ``cluster-smoke`` job (and runnable standalone:
``PYTHONPATH=src python -m benchmarks.cluster_smoke``).
"""

from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

from repro.scenario import FaultSpec, Scenario, get_preset

from .common import Timer, csv_row, save_artifact

# Smoke scale: 60k requests over a 20k-object catalogue (the preset is
# 3M x 1e6 at paper scale). Phase windows stay thousands of requests
# wide, so phase hit rates carry ~0.005 Monte-Carlo noise — well inside
# the recovery tolerance.
REQUESTS_FACTOR = 0.02
CATALOGUE_FACTOR = 0.02
RECOVERY_TOL = 0.02

# Parallel-executor leg: K=16 over 8 workers on a 4x-longer trace (the
# pool's fork/teardown cost must be amortized before a wall-clock
# ratio means anything). The floor is deliberately loose — the
# contract is bit-identity; the floor only proves the pool is not
# degenerate — and applies only where the hardware can express a
# speedup at all.
PARALLEL_K = 16
PARALLEL_WORKERS = 8
PARALLEL_REQUESTS_MULT = 4
SPEEDUP_FLOOR = 1.3
SPEEDUP_MIN_CORES = 4


def scenario() -> Scenario:
    return get_preset("cluster_failover").scaled(
        requests=REQUESTS_FACTOR, catalogue=CATALOGUE_FACTOR
    )


def _outage_window_mean(cl: dict, lo: int, hi: int) -> float:
    """Mean windowed hit rate over ``[lo, hi)`` (full windows only)."""
    w = cl["windows"]
    vals = [
        hr
        for start, hr in zip(w["starts"], w["hit_rate"])
        if start >= lo and start + w["size"] <= hi
    ]
    return float(np.mean(vals))


def main() -> dict:
    sc = scenario()
    counterfactual = dataclasses.replace(
        sc, system=dataclasses.replace(sc.system, faults=FaultSpec())
    )
    with Timer() as tm:
        rep = sc.run()
        rep2 = sc.run()
        rep0 = counterfactual.run()  # same trace, no faults

    if not rep.same_estimates(rep2):
        raise RuntimeError(
            "cluster run is not bit-reproducible under a fixed seed"
        )
    cl = rep.extras["cluster"]
    if cl != rep2.extras["cluster"]:
        raise RuntimeError("cluster telemetry differs between seeded runs")

    n = sc.n_requests
    fail_idx, recover_idx = round(0.4 * n), round(0.6 * n)
    during_faulted = _outage_window_mean(cl, fail_idx, recover_idx)
    during_healthy = _outage_window_mean(
        rep0.extras["cluster"], fail_idx, recover_idx
    )
    hits_lost = rep0.extras["n_hit_list"] - rep.extras["n_hit_list"]
    if hits_lost <= 0 or during_faulted >= during_healthy:
        raise RuntimeError(
            "node outage not visible against the fault-free "
            f"counterfactual: hits_lost={hits_lost}, outage windows "
            f"{during_faulted:.4f} (faulted) vs {during_healthy:.4f} "
            "(healthy)"
        )
    if cl["retries"]["total"] <= 0:
        raise RuntimeError("failover never engaged (zero retries)")

    pre = cl["phases"]["pre_fault"]["hit_rate"]
    post = cl["phases"]["post_recovery"]["hit_rate"]
    if post < pre - RECOVERY_TOL:
        raise RuntimeError(
            f"post-recovery hit rate {post:.4f} did not return to within "
            f"{RECOVERY_TOL} of the pre-fault baseline {pre:.4f}"
        )
    if not cl["recovery"]["recovered"]:
        raise RuntimeError(
            "recovery detector never found a window back at baseline"
        )

    # --- parallel executor: exactness always, speed where possible ---
    par_base = dataclasses.replace(
        sc,
        name="cluster_smoke_parallel",
        n_requests=sc.n_requests * PARALLEL_REQUESTS_MULT,
        warmup=sc.warmup * PARALLEL_REQUESTS_MULT,
        system=dataclasses.replace(
            sc.system, nodes=PARALLEL_K, faults=FaultSpec()
        ),
    )
    t0 = time.perf_counter()
    seq16 = par_base.run()
    t_seq = time.perf_counter() - t0
    par_sc = dataclasses.replace(
        par_base,
        system=dataclasses.replace(
            par_base.system, executor="parallel", workers=PARALLEL_WORKERS
        ),
    )
    t0 = time.perf_counter()
    par16 = par_sc.run()
    t_par = time.perf_counter() - t0
    if not par16.same_estimates(seq16):
        raise RuntimeError(
            f"parallel executor (K={PARALLEL_K}, "
            f"workers={PARALLEL_WORKERS}) is not bit-identical to the "
            "sequential reference"
        )
    if par16.extras["cluster"] != seq16.extras["cluster"]:
        raise RuntimeError(
            "parallel cluster telemetry differs from sequential"
        )
    cores = os.cpu_count() or 1
    speedup = t_seq / max(t_par, 1e-9)
    if cores >= SPEEDUP_MIN_CORES and speedup < SPEEDUP_FLOOR:
        raise RuntimeError(
            f"parallel executor speedup {speedup:.2f}x on {cores} cores "
            f"is below the {SPEEDUP_FLOOR}x floor (K={PARALLEL_K}, "
            f"workers={PARALLEL_WORKERS})"
        )

    payload = {
        "scenario": sc.to_dict(),
        "backend": rep.backend,
        "pre_fault_hit_rate": pre,
        "during_window_hit_rate": during_faulted,
        "counterfactual_window_hit_rate": during_healthy,
        "hits_lost_to_outage": int(hits_lost),
        "post_recovery_hit_rate": post,
        "recovery_tol": RECOVERY_TOL,
        "requests_to_baseline": cl["recovery"]["requests_to_baseline"],
        "degraded_requests": cl["retries"]["degraded_requests"],
        "retries": cl["retries"]["total"],
        "deterministic": True,
        "parallel": {
            "K": PARALLEL_K,
            "workers": PARALLEL_WORKERS,
            "cpu_count": cores,
            "sequential_seconds": round(t_seq, 3),
            "parallel_seconds": round(t_par, 3),
            "speedup": round(speedup, 3),
            "speedup_floor": SPEEDUP_FLOOR,
            "floor_enforced": cores >= SPEEDUP_MIN_CORES,
            "bit_identical": True,
        },
        "wall_seconds": round(tm.seconds, 3),
    }
    save_artifact("cluster_smoke", payload)
    print(
        f"# cluster smoke: outage windows {during_faulted:.4f} vs "
        f"{during_healthy:.4f} healthy ({hits_lost} hits lost), "
        f"pre={pre:.4f} post={post:.4f} (tol {RECOVERY_TOL}), recovered "
        f"in {cl['recovery']['requests_to_baseline']} requests, "
        f"deterministic across reruns"
    )
    print(
        f"# parallel executor: K={PARALLEL_K} workers={PARALLEL_WORKERS} "
        f"bit-identical, speedup={speedup:.2f}x on {cores} cores "
        f"(floor {SPEEDUP_FLOOR}x enforced at >= {SPEEDUP_MIN_CORES})"
    )
    csv_row(
        "cluster_smoke",
        tm.seconds * 1e6 / max(3 * sc.n_requests, 1),
        f"hits_lost={hits_lost};pre={pre:.4f};post={post:.4f};"
        f"par_speedup={speedup:.2f}x@{cores}cores",
    )
    return payload


if __name__ == "__main__":
    main()
