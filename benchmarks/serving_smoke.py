"""Serving-subsystem smoke: prove sharing gain + determinism in CI.

Runs the ``serving_multitenant`` preset at smoke scale twice — once
with a 90%-shared prompt pool and once fully disjoint — under the same
seed and geometry, and enforces the hard assertions the subsystem
promises:

* **sharing pays** — the overlap cell's prefix-block hit ratio strictly
  exceeds the disjoint cell's (hit-ratio gain > 1.0; Prop. 3.1 in
  serving form);
* **onboarding is gated** — the admission record is present, every
  seated tenant carries a predicted-SLA entry, and the committed
  integer allocations fit the physical block budget;
* **the run is deterministic** — a second run of the overlap cell under
  the same seed reproduces the ServingReport bit for bit (the compiled
  trace, the admission episode, and the derived economics add no hidden
  entropy).

Used by the CI ``serving-smoke`` job (and runnable standalone:
``PYTHONPATH=src python -m benchmarks.serving_smoke``).
"""

from __future__ import annotations

from repro.scenario import Scenario, get_preset

from .common import Timer, csv_row, save_artifact

# Smoke scale: 100k block events per cell (the preset is 10M at paper
# scale) — the C backend clears both cells in well under a second.
REQUESTS_FACTOR = 0.01


def scenario(shared_frac: float) -> Scenario:
    return get_preset(
        "serving_multitenant", shared_frac=shared_frac
    ).scaled(requests=REQUESTS_FACTOR)


def main() -> dict:
    overlap_sc = scenario(0.9)
    disjoint_sc = scenario(0.0)
    with Timer() as tm:
        rep = overlap_sc.run()
        rep2 = overlap_sc.run()
        rep0 = disjoint_sc.run()

    sv, sv2, sv0 = rep.serving, rep2.serving, rep0.serving
    if sv != sv2:
        raise RuntimeError(
            "serving run is not bit-reproducible under a fixed seed"
        )

    gain = sv["prefix_hit_block_ratio"] / max(
        sv0["prefix_hit_block_ratio"], 1e-9
    )
    if gain <= 1.0:
        raise RuntimeError(
            "object sharing shows no hit-ratio gain: overlap "
            f"{sv['prefix_hit_block_ratio']:.4f} vs disjoint "
            f"{sv0['prefix_hit_block_ratio']:.4f}"
        )

    adm = sv["admission"]
    if adm is None or not adm["active_tenants"]:
        raise RuntimeError("admission-gated onboarding record missing")
    if len(adm["predicted_sla_hit_rate"]) != len(adm["active_tenants"]):
        raise RuntimeError("predicted-SLA entries do not cover the seated set")
    if sum(adm["b_virtual_int"]) > adm["capacity"]:
        raise RuntimeError(
            "committed integer allocations exceed the physical budget: "
            f"{sum(adm['b_virtual_int'])} > {adm['capacity']:.0f}"
        )

    payload = {
        "scenario": overlap_sc.to_dict(),
        "disjoint_scenario": disjoint_sc.to_dict(),
        "backend": rep.backend,
        "overlap_hit_ratio": sv["prefix_hit_block_ratio"],
        "disjoint_hit_ratio": sv0["prefix_hit_block_ratio"],
        "hit_ratio_gain": gain,
        "tenants_active": len(adm["active_tenants"]),
        "tenants_declared": sv["tenants"],
        "overbooked": adm["overbooked"],
        "overbooking_gain": adm["overbooking_gain"],
        "max_abs_sla_gap": adm["max_abs_sla_gap"],
        "prefill_flops_saved": sv["prefill_flops_saved"],
        "deterministic": True,
        "wall_seconds": round(tm.seconds, 3),
    }
    save_artifact("serving_smoke", payload)
    print(
        f"# serving smoke: hit ratio {sv['prefix_hit_block_ratio']:.4f} "
        f"(90% shared) vs {sv0['prefix_hit_block_ratio']:.4f} (disjoint) "
        f"= {gain:.2f}x gain; {len(adm['active_tenants'])}/{sv['tenants']} "
        f"tenants seated (overbooking {adm['overbooking_gain']:.2f}), "
        f"SLA gap {adm['max_abs_sla_gap']:.4f}, deterministic across reruns"
    )
    csv_row(
        "serving_smoke",
        tm.seconds * 1e6 / max(3 * overlap_sc.n_requests, 1),
        f"gain={gain:.3f};active={len(adm['active_tenants'])}",
    )
    return payload


if __name__ == "__main__":
    main()
