"""Section IV-D evaluation: Reducing Ripple Evictions (RRE).

Runs the same trace through the base shared cache and RRE variants
(slack thresholds +/- delayed batch evictions) and reports the on-path
ripple-eviction reduction vs the memory given back — the paper leaves
this as "ongoing work"; this benchmark completes it.

Both systems run on the array engine: ``ripple_allocations`` (b_hat) and
``batch_interval`` are native ``SimParams`` knobs, equivalent to
:class:`repro.core.rre.RRECache` over the reference cache (the
equivalence tests cover both mechanisms).
"""

from __future__ import annotations

import numpy as np

from repro.core import RREConfig, SimParams, rate_matrix, sample_trace, simulate_trace

from .common import FIG2_ALPHAS, Timer, csv_row, fig2_scale, save_artifact


def main() -> dict:
    b, n_objects, B, n_requests = fig2_scale()
    n_requests = n_requests // 3  # RRE sweep runs multiple configs
    lam = rate_matrix(n_objects, list(FIG2_ALPHAS))
    trace = sample_trace(lam, n_requests, seed=31)
    warmup = n_requests // 10

    results = {}
    with Timer() as tm:
        for slack in (0.1, 0.25, 0.5):
            for batch in (0, 200):
                cfg = RREConfig(slack_frac=slack, batch_interval=batch)
                b_hat = tuple(cfg.ripple_allocations(list(b)))
                capacity = sum(b_hat)
                base = simulate_trace(
                    SimParams(allocations=tuple(b), physical_capacity=capacity),
                    trace,
                    n_objects,
                    warmup=warmup,
                    ripple_from=0,
                )
                rre = simulate_trace(
                    SimParams(
                        allocations=tuple(b),
                        physical_capacity=capacity,
                        ripple_allocations=b_hat,
                        batch_interval=batch,
                    ),
                    trace,
                    n_objects,
                    warmup=warmup,
                    ripple_from=0,
                )
                key = f"slack={slack},batch={batch}"
                results[key] = {
                    "base_ripple": base.n_ripple,
                    "rre_ripple_onpath": rre.n_ripple,
                    "rre_batch_evictions": rre.n_batch_evictions,
                    "base_frac_multi": base.frac_multi_eviction,
                    "rre_frac_multi": rre.frac_multi_eviction,
                    "memory_giveback": sum(b_hat) - sum(b),
                    "reduction": 1.0 - rre.n_ripple / max(base.n_ripple, 1),
                }

    payload = {"allocations": list(b), "n_requests": n_requests,
               "engine": "fastsim", "results": results}
    save_artifact("rre", payload)

    print("# RRE evaluation (Section IV-D)")
    print("# config                 base_ripple  rre_onpath  batch_ev  giveback  reduction")
    for key, r in results.items():
        print(
            f"  {key:22s} {r['base_ripple']:11d} {r['rre_ripple_onpath']:11d} "
            f"{r['rre_batch_evictions']:9d} {r['memory_giveback']:9d} "
            f"{r['reduction']:8.1%}"
        )
    best = max(results.values(), key=lambda r: r["reduction"])
    csv_row(
        "rre",
        tm.seconds * 1e6 / (len(results) * 2 * n_requests),
        f"best_onpath_ripple_reduction={best['reduction']:.3f}",
    )
    return payload


if __name__ == "__main__":
    main()
