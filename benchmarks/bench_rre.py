"""Section IV-D evaluation: Reducing Ripple Evictions (RRE).

Runs the same trace through the base shared cache and RRE variants
(slack thresholds +/- delayed batch evictions) and reports the on-path
ripple-eviction reduction vs the memory given back — the paper leaves
this as "ongoing work"; this benchmark completes it.
"""

from __future__ import annotations

import numpy as np

from repro.core import RREConfig, compare_ripple, rate_matrix, sample_trace

from .common import FIG2_ALPHAS, Timer, csv_row, fig2_scale, save_artifact


def main() -> dict:
    b, n_objects, B, n_requests = fig2_scale()
    n_requests = n_requests // 3  # RRE sweep runs multiple configs
    lam = rate_matrix(n_objects, list(FIG2_ALPHAS))
    trace = sample_trace(lam, n_requests, seed=31)
    lengths = np.ones(n_objects, dtype=np.int64)

    results = {}
    with Timer() as tm:
        for slack in (0.1, 0.25, 0.5):
            for batch in (0, 200):
                cfg = RREConfig(slack_frac=slack, batch_interval=batch)
                out = compare_ripple(
                    trace.proxies, trace.objects, lengths, list(b), cfg
                )
                key = f"slack={slack},batch={batch}"
                base, rre = out["base"], out["rre"]
                results[key] = {
                    "base_ripple": base.n_ripple,
                    "rre_ripple_onpath": rre.n_ripple,
                    "rre_batch_evictions": out["rre_batch_evictions"],
                    "base_frac_multi": base.frac_multi_eviction,
                    "rre_frac_multi": rre.frac_multi_eviction,
                    "memory_giveback": out["memory_giveback"],
                    "reduction": 1.0
                    - rre.n_ripple / max(base.n_ripple, 1),
                }

    payload = {"allocations": list(b), "n_requests": n_requests, "results": results}
    save_artifact("rre", payload)

    print("# RRE evaluation (Section IV-D)")
    print("# config                 base_ripple  rre_onpath  batch_ev  giveback  reduction")
    for key, r in results.items():
        print(
            f"  {key:22s} {r['base_ripple']:11d} {r['rre_ripple_onpath']:11d} "
            f"{r['rre_batch_evictions']:9d} {r['memory_giveback']:9d} "
            f"{r['reduction']:8.1%}"
        )
    best = max(results.values(), key=lambda r: r["reduction"])
    csv_row(
        "rre",
        tm.seconds * 1e6 / (len(results) * n_requests),
        f"best_onpath_ripple_reduction={best['reduction']:.3f}",
    )
    return payload


if __name__ == "__main__":
    main()
