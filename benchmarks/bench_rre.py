"""Section IV-D evaluation: Reducing Ripple Evictions (RRE).

Sweeps the ``rre`` preset over slack thresholds and delayed-batch
intervals; for each configuration the base system is the same scenario
with the slack stripped (identical workload, seed, and physical
capacity), so the comparison isolates the RRE mechanisms. The paper
leaves this study as "ongoing work"; this benchmark completes it.
"""

from __future__ import annotations

import dataclasses

from repro.scenario import get_preset

from .common import Timer, csv_row, fig2_scale_factors, save_artifact


def main() -> dict:
    factors = fig2_scale_factors()
    results = {}
    scenarios = {}
    n_requests = 0
    n_runs = 0
    # One Workload instance for the whole sweep: every configuration
    # sees the identical seed-31 trace, and the cached (9, N) rate
    # matrix is built once instead of per run.
    workload = get_preset("rre").scaled(*factors).workload
    with Timer() as tm:
        for slack in (0.1, 0.25, 0.5):
            # Base: same trace, same physical capacity (which depends
            # only on the slack), no slack/batch — one run per slack.
            rre_sc = dataclasses.replace(
                get_preset("rre", slack_frac=slack).scaled(*factors),
                workload=workload,
            )
            n_requests = rre_sc.n_requests
            b = rre_sc.system.allocations
            b_hat = rre_sc.system.b_hat()
            base_sc = dataclasses.replace(
                rre_sc,
                name="rre_base",
                system=dataclasses.replace(
                    rre_sc.system,
                    slack_frac=0.0,
                    batch_interval=0,
                    physical_capacity=rre_sc.system.capacity(),
                ),
            )
            base = base_sc.run()
            n_runs += 1
            for batch in (0, 200):
                rre_sc = dataclasses.replace(
                    get_preset(
                        "rre", slack_frac=slack, batch_interval=batch
                    ).scaled(*factors),
                    workload=workload,
                )
                rre = rre_sc.run()
                n_runs += 1
                key = f"slack={slack},batch={batch}"
                scenarios[key] = rre_sc.to_dict()
                results[key] = {
                    "base_ripple": base.ripple["n_ripple"],
                    "rre_ripple_onpath": rre.ripple["n_ripple"],
                    "rre_batch_evictions": rre.ripple["n_batch_evictions"],
                    "base_frac_multi": base.ripple["frac_multi_eviction"],
                    "rre_frac_multi": rre.ripple["frac_multi_eviction"],
                    "memory_giveback": sum(b_hat) - sum(b),
                    "reduction": 1.0
                    - rre.ripple["n_ripple"] / max(base.ripple["n_ripple"], 1),
                }

    payload = {
        "preset": "rre",
        "scenarios": scenarios,
        "allocations": list(b),
        "n_requests": n_requests,
        "engine": rre.backend,
        "results": results,
    }
    save_artifact("rre", payload)

    print("# RRE evaluation (Section IV-D)")
    print("# config                 base_ripple  rre_onpath  batch_ev  giveback  reduction")
    for key, r in results.items():
        print(
            f"  {key:22s} {r['base_ripple']:11d} {r['rre_ripple_onpath']:11d} "
            f"{r['rre_batch_evictions']:9d} {r['memory_giveback']:9d} "
            f"{r['reduction']:8.1%}"
        )
    best = max(results.values(), key=lambda r: r["reduction"])
    csv_row(
        "rre",
        tm.seconds * 1e6 / (n_runs * n_requests),
        f"best_onpath_ripple_reduction={best['reduction']:.3f}",
    )
    return payload


if __name__ == "__main__":
    main()
