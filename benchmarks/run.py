"""Benchmark harness: one module per paper table/figure + the serving
sweep. Prints ``name,us_per_call,derived`` CSV rows (one per experiment)
and writes JSON artifacts under ``benchmarks/artifacts/``.

Usage:
    PYTHONPATH=src python -m benchmarks.run            # reduced sizes
    REPRO_FULL=1 PYTHONPATH=src python -m benchmarks.run   # paper scale
    PYTHONPATH=src python -m benchmarks.run table2_ws rre  # subset
    PYTHONPATH=src python -m benchmarks.run --quick    # CI smoke scale
    PYTHONPATH=src python -m benchmarks.run --list     # what can run

After a run, ``python -m benchmarks.report`` renders EXPERIMENTS.md from
the artifacts.
"""

from __future__ import annotations

import sys
import traceback

BENCHES = [
    ("table2_ws", "benchmarks.bench_table2_ws"),          # deterministic, fast
    ("table1_sim", "benchmarks.bench_table1_sim"),
    ("table3_noshare", "benchmarks.bench_table3_noshare"),
    ("j2_bounds", "benchmarks.bench_j2_bounds"),
    ("fig2_ripple", "benchmarks.bench_fig2_ripple"),      # also covers Table V
    ("rre", "benchmarks.bench_rre"),
    ("slru", "benchmarks.bench_slru"),
    ("simthroughput", "benchmarks.bench_simthroughput"),  # engine speedup
    ("large_n_smoke", "benchmarks.large_n_smoke"),        # streaming + RSS guard
    ("admission", "benchmarks.bench_admission"),
    ("cluster", "benchmarks.bench_cluster"),              # K x failure-rate sweep
    ("serving", "benchmarks.bench_serving"),      # tenants x overlap x mix
    ("serving_smoke", "benchmarks.serving_smoke"),
]


def list_available() -> None:
    """Enumerate benchmarks and the scenario presets they run on."""
    from repro.scenario import list_presets

    print("benchmarks (python -m benchmarks.run <name> ...):")
    for name, module in BENCHES:
        print(f"  {name:16s} {module}")
    print("\nscenario presets (repro.scenario.get_preset(name)):")
    for name, desc in list_presets().items():
        print(f"  {name:16s} {desc}")


def main() -> None:
    import importlib

    args = sys.argv[1:]
    if "--list" in args:
        list_available()
        return
    if "--quick" in args:
        args = [a for a in args if a != "--quick"]
        from benchmarks import common

        common.QUICK = True
    selected = set(args)
    known = {name for name, _ in BENCHES}
    unknown = selected - known
    if unknown:
        print(f"unknown benchmark(s): {', '.join(sorted(unknown))}")
        print(f"available: {', '.join(sorted(known))}")
        sys.exit(2)
    outcomes = []  # (name, error-or-None), in run order
    for name, module in BENCHES:
        if selected and name not in selected:
            continue
        print(f"\n===== {name} =====")
        try:
            mod = importlib.import_module(module)
            mod.main()
        except Exception as e:  # keep the harness going; report at the end
            outcomes.append((name, e))
            traceback.print_exc()
            print(f"{name},nan,FAILED:{type(e).__name__}")
        else:
            outcomes.append((name, None))
    # Per-bench summary: one PASS/FAIL line each, so a crashed bench is
    # visible in the log tail and the harness exit code (CI greps both).
    print("\n----- summary -----")
    for name, err in outcomes:
        status = "PASS" if err is None else f"FAIL ({type(err).__name__})"
        print(f"{name:16s} {status}")
    failures = [(n, e) for n, e in outcomes if e is not None]
    if failures:
        print(f"\n{len(failures)}/{len(outcomes)} benchmark(s) failed: "
              + ", ".join(n for n, _ in failures))
        sys.exit(1)
    print(f"\nall {len(outcomes)} selected benchmark(s) passed")


if __name__ == "__main__":
    main()
