"""Paper Section V, J=2 discussion: L1 underestimates hit probabilities
(by ~30 % in the paper's setting) while L2 overestimates — together they
bracket the truth; Lstar is only marginally above L1.

One ``j2_bounds`` preset, four estimators: the Monte-Carlo run plus
``with_estimator("working_set", attribution=...)`` under L1/Lstar/L2 —
the scenario layer makes the simulator and the three analytic models
interchangeable views of the same experiment.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.scenario import get_preset

from .common import RANKS, Timer, csv_row, save_artifact, section5_scale


def main() -> dict:
    replications = 4
    sc = get_preset("j2_bounds").scaled(*section5_scale())
    sc = dataclasses.replace(
        sc,
        estimator=dataclasses.replace(
            sc.estimator, replications=replications
        ),
    )
    n_requests = sc.n_requests

    with Timer() as tm:
        sim = sc.run()
    # densify: at REPRO_FULL the run auto-streams (sparse occupancy) and
    # the head-rank bias below slices the (J, N) matrix (N=1000).
    # With replications this is the cross-replica mean trajectory.
    h_sim = sim.dense_hit_prob()
    try:
        h_std = sim.hit_prob_std()
    except ValueError:  # sparse ensemble: per-object stack not retained
        h_std = None

    sols = {
        kind: sc.with_estimator("working_set", attribution=kind).run()
        for kind in ("L1", "Lstar", "L2")
    }

    # Head-rank summary (tails are dominated by trajectory noise).
    head = slice(0, 100)
    rows = {}
    under_L1, over_L2 = [], []
    for i in range(2):
        hs = h_sim[i, head]
        rows[i] = {
            "sim": sim.hit_prob_at_ranks(i, RANKS),
            **(
                {"sim_std": [float(h_std[i, r - 1]) for r in RANKS]}
                if h_std is not None
                else {}
            ),
            **{
                kind: rep.hit_prob_at_ranks(i, RANKS)
                for kind, rep in sols.items()
            },
        }
        for kind, rep in sols.items():
            bias = float(
                np.mean((rep.hit_prob[i, head] - hs) / np.maximum(hs, 1e-6))
            )
            rows[i][f"bias_{kind}"] = bias
        under_L1.append(rows[i]["bias_L1"])
        over_L2.append(rows[i]["bias_L2"])

    l1_under = all(x < 0 for x in under_L1)
    l2_over = all(x > -0.02 for x in over_L2) and np.mean(over_L2) > np.mean(under_L1)

    payload = {
        "preset": "j2_bounds",
        "scenario": sc.to_dict(),
        "replications": replications,
        "rows": rows,
        "L1_underestimates": l1_under,
        "L2_over_or_upper": l2_over,
        "mean_bias": {"L1": float(np.mean(under_L1)), "L2": float(np.mean(over_L2))},
    }
    save_artifact("j2_bounds", payload)

    alphas = sc.workload.alphas
    b = sc.system.allocations
    print(f"# J=2 bounds (alphas={alphas}, b={b})")
    print("# i   rank:      1        10       100      1000")
    for i in range(2):
        print(f"  {i}  sim    " + "  ".join(f"{x:.4f}" for x in rows[i]["sim"]))
        for kind in ("L1", "Lstar", "L2"):
            print(f"  {i}  {kind:5s}  " + "  ".join(f"{x:.4f}" for x in rows[i][kind])
                  + f"   bias={rows[i][f'bias_{kind}']:+.3f}")
    print(f"# L1 underestimates: {l1_under}; L2 upper bound: {l2_over}")
    print("# paper claims L1 ~30% under at J=2; in our implementation L1 is")
    print("# near-unbiased at J=2 across workloads (see EXPERIMENTS.md "
          "§Reproduction discrepancies); the L2-overestimate claim reproduces.")
    csv_row(
        "j2_bounds",
        tm.seconds * 1e6 / n_requests,
        f"bias_L1={np.mean(under_L1):+.3f};bias_L2={np.mean(over_L2):+.3f}",
    )
    return payload


if __name__ == "__main__":
    main()
