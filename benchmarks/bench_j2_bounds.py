"""Paper Section V, J=2 discussion: L1 underestimates hit probabilities
(by ~30 % in the paper's setting) while L2 overestimates — together they
bracket the truth; Lstar is only marginally above L1.

We simulate a J=2 shared cache (occupancy estimator) and solve the
working-set approximation under all three attribution models.
"""

from __future__ import annotations

import numpy as np

from repro.core import SimParams, rate_matrix, sample_trace, simulate_trace, solve_workingset

from .common import N_OBJECTS, RANKS, Timer, csv_row, save_artifact, table1_requests


def main() -> dict:
    alphas = (0.75, 1.0)
    b = (32, 32)
    n_requests = table1_requests()
    lam = rate_matrix(N_OBJECTS, list(alphas))
    lengths = np.ones(N_OBJECTS)

    with Timer() as tm:
        trace = sample_trace(lam, n_requests, seed=5)
        h_sim = simulate_trace(
            SimParams(allocations=b, physical_capacity=N_OBJECTS),
            trace,
            N_OBJECTS,
            warmup=n_requests // 15,
        ).occupancy

    sols = {
        kind: solve_workingset(lam, lengths, np.array(b, float), attribution=kind)
        for kind in ("L1", "Lstar", "L2")
    }

    # Head-rank summary (tails are dominated by trajectory noise).
    head = slice(0, 100)
    rows = {}
    under_L1, over_L2 = [], []
    for i in range(2):
        sim = h_sim[i, head]
        rows[i] = {
            "sim": [float(h_sim[i, k - 1]) for k in RANKS],
            **{
                kind: [float(s.h[i, k - 1]) for k in RANKS]
                for kind, s in sols.items()
            },
        }
        for kind, s in sols.items():
            bias = float(np.mean((s.h[i, head] - sim) / np.maximum(sim, 1e-6)))
            rows[i][f"bias_{kind}"] = bias
        under_L1.append(rows[i]["bias_L1"])
        over_L2.append(rows[i]["bias_L2"])

    l1_under = all(x < 0 for x in under_L1)
    l2_over = all(x > -0.02 for x in over_L2) and np.mean(over_L2) > np.mean(under_L1)

    payload = {
        "alphas": alphas,
        "b": b,
        "rows": rows,
        "L1_underestimates": l1_under,
        "L2_over_or_upper": l2_over,
        "mean_bias": {"L1": float(np.mean(under_L1)), "L2": float(np.mean(over_L2))},
    }
    save_artifact("j2_bounds", payload)

    print(f"# J=2 bounds (alphas={alphas}, b={b})")
    print("# i   rank:      1        10       100      1000")
    for i in range(2):
        print(f"  {i}  sim    " + "  ".join(f"{x:.4f}" for x in rows[i]["sim"]))
        for kind in ("L1", "Lstar", "L2"):
            print(f"  {i}  {kind:5s}  " + "  ".join(f"{x:.4f}" for x in rows[i][kind])
                  + f"   bias={rows[i][f'bias_{kind}']:+.3f}")
    print(f"# L1 underestimates: {l1_under}; L2 upper bound: {l2_over}")
    print("# paper claims L1 ~30% under at J=2; in our implementation L1 is")
    print("# near-unbiased at J=2 across workloads (see EXPERIMENTS.md "
          "§Reproduction discrepancies); the L2-overestimate claim reproduces.")
    csv_row(
        "j2_bounds",
        tm.seconds * 1e6 / n_requests,
        f"bias_L1={np.mean(under_L1):+.3f};bias_L2={np.mean(over_L2):+.3f}",
    )
    return payload


if __name__ == "__main__":
    main()
