"""Large-N streaming smoke: prove the sparse/chunked estimator engages.

Runs a scenario sized so the runner's *auto* streaming selection must
trigger (``n_requests * J`` crosses the request-cell threshold) and
enforces two hard assertions:

* the report says the streaming path ran (``extras['streaming']`` True,
  sparse hit-probability representation) — failing this means the dense
  path was silently used;
* peak RSS above the pre-run baseline stays under ``RSS_BUDGET_MB``.
  The one-shot dense path cannot pass this: materializing the
  6M-request trace alone costs ~72 MB (plus ~160 MB of sampling
  transients), while the streaming path holds one 250k-request chunk
  plus the touched-set engine state.

Used by the CI ``large-n-smoke`` job (and runnable standalone:
``PYTHONPATH=src python -m benchmarks.large_n_smoke``).
"""

from __future__ import annotations

from repro.scenario import Estimator, Scenario, System, Workload

from .common import PeakRSS, Timer, csv_row, save_artifact

N_OBJECTS = 200_000
N_REQUESTS = 6_000_000  # x J=3 proxies = 18M request cells: auto-streams
RSS_BUDGET_MB = 96.0


def scenario() -> Scenario:
    return Scenario(
        name="large_n_smoke",
        description=(
            "Large-N streaming smoke: Section-V-shaped workload scaled to "
            f"N={N_OBJECTS:,} objects x {N_REQUESTS:,} requests, auto "
            "streaming + sparse occupancy, enforced peak-RSS budget."
        ),
        workload=Workload(
            kind="irm", n_objects=N_OBJECTS, alphas=(0.75, 0.5, 1.0)
        ),
        system=System(
            variant="lru", allocations=(600, 600, 600), physical_capacity=2000
        ),
        estimator=Estimator("monte_carlo"),  # streaming=None -> auto
        n_requests=N_REQUESTS,
        seed=17,
    )


def main() -> dict:
    sc = scenario()
    with PeakRSS() as pr, Timer() as tm:
        rep = sc.run()

    streaming = bool(rep.extras.get("streaming"))
    if not streaming or not rep.hit_prob_is_sparse:
        raise RuntimeError(
            "large-N scenario did not take the streaming/sparse path "
            f"(streaming={streaming}, sparse={rep.hit_prob_is_sparse}) — "
            "the dense path was silently used"
        )
    if pr.supported and pr.delta_mb > RSS_BUDGET_MB:
        raise RuntimeError(
            f"peak RSS {pr.delta_mb:.1f} MB above baseline exceeds the "
            f"{RSS_BUDGET_MB:.0f} MB streaming budget — dense-path "
            "memory behaviour detected"
        )

    payload = {
        "scenario": sc.to_dict(),
        "backend": rep.backend,
        "streaming": streaming,
        "chunk_size": rep.extras.get("chunk_size"),
        "sparse_hit_prob": rep.hit_prob_is_sparse,
        "touched_objects": int(rep.hit_prob.nnz),
        "n_objects": N_OBJECTS,
        "overall_hit_rate": float(rep.overall_hit_rate),
        "peak_rss_delta_mb": round(pr.delta_mb, 2),
        "rss_budget_mb": RSS_BUDGET_MB,
        "rss_supported": pr.supported,
        "engine_requests_per_sec": float(rep.throughput_rps),
        "wall_seconds": round(tm.seconds, 3),
    }
    save_artifact("large_n_smoke", payload)
    print(
        f"# large-N smoke: backend={rep.backend} streaming={streaming} "
        f"touched={payload['touched_objects']:,}/{N_OBJECTS:,} objects, "
        f"peak RSS +{pr.delta_mb:.1f} MB (budget {RSS_BUDGET_MB:.0f} MB), "
        f"{rep.throughput_rps:,.0f} req/s"
    )
    csv_row(
        "large_n_smoke",
        tm.seconds * 1e6 / max(N_REQUESTS, 1),
        f"peak_rss_mb={pr.delta_mb:.1f};streaming={streaming}",
    )
    return payload


if __name__ == "__main__":
    main()
