"""Multi-tenant KV prefix-cache serving sweep on the fast engine.

The paper's Prop. 3.1 economics transplanted to LLM serving, at trace
scale: the ``serving_multitenant`` preset compiles each cell's
prompt-stream model to a (tenant, KV-block) trace and drives it through
the fastsim C backend — millions of block events per cell instead of
the hundreds the per-call reference engine manages. Three axes:

* **tenants** — sharing partners at fixed overlap (each new tenant
  splits the shared head blocks' charge further, eq. (5));
* **prefix overlap** — ``shared_frac`` from fully disjoint prompt pools
  to near-total system-prompt reuse (the overlap-vs-disjoint gain is
  the headline number);
* **traffic mix** — uniform, ramped, and head-heavy per-tenant request
  rates over the same geometry.

Every cell runs admission-gated onboarding (``B = 4 b*`` against
``sum b* = T b*``), so the artifact also records how many tenants the
eq. (13) test seats and the realized-vs-predicted SLA gap.
"""

from __future__ import annotations

import dataclasses

from repro.scenario import Scenario, get_preset

from .common import FULL, Timer, csv_row, quick_mode, save_artifact

TENANT_SWEEP = (2, 4, 6, 8)
OVERLAP_SWEEP = (0.0, 0.5, 0.75, 0.9)
BASE_TENANTS = 6
BASE_OVERLAP = 0.75


def requests_factor() -> float:
    """10M block events per cell at paper scale; ~2M by default."""
    if FULL:
        return 1.0
    return 0.01 if quick_mode() else 0.2


def _mix(kind: str, n_tenants: int):
    if kind == "uniform":
        return tuple(1.0 for _ in range(n_tenants))
    if kind == "ramp":  # the preset default
        return tuple(1.0 + 0.25 * i for i in range(n_tenants))
    if kind == "head":  # one hot tenant dominates
        return tuple(4.0 if i == 0 else 1.0 for i in range(n_tenants))
    raise ValueError(kind)


def scenario(n_tenants: int, shared_frac: float, mix: str) -> Scenario:
    sc = get_preset(
        "serving_multitenant", n_tenants=n_tenants, shared_frac=shared_frac
    ).scaled(requests=requests_factor())
    return dataclasses.replace(
        sc,
        name=f"serving/T{n_tenants}/f{shared_frac:g}/{mix}",
        workload=dataclasses.replace(
            sc.workload, proxy_rates=_mix(mix, n_tenants)
        ),
    )


def _cell(sc: Scenario) -> dict:
    rep = sc.run()
    sv = rep.serving
    adm = sv["admission"]
    return {
        "hit_ratio": sv["prefix_hit_block_ratio"],
        "n_block_events": sv["n_block_events"],
        "n_serving_requests": sv["n_serving_requests"],
        "prefill_flops_saved": sv["prefill_flops_saved"],
        "bytes_shared_lb": sv["bytes_shared_lb"],
        "latency_mean_s": sv["latency_mean_s"],
        "latency_p99_s": sv["latency_p99_s"],
        "latency_cold_s": sv["latency_cold_s"],
        "tenants_active": len(adm["active_tenants"]),
        "tenants_declared": sv["tenants"],
        "n_rejected": adm["n_rejected"],
        "overbooked": adm["overbooked"],
        "overbooking_gain": adm["overbooking_gain"],
        "max_abs_sla_gap": adm["max_abs_sla_gap"],
        "backend": rep.backend,
        "throughput_rps": rep.throughput_rps,
        "serving": sv,
    }


def main() -> dict:
    cells: dict = {}
    scenarios: dict = {}
    specs: dict = {}
    for t in TENANT_SWEEP:
        specs[f"T{t}/f{BASE_OVERLAP:g}/ramp"] = (t, BASE_OVERLAP, "ramp")
    for f in OVERLAP_SWEEP:
        specs[f"T{BASE_TENANTS}/f{f:g}/ramp"] = (BASE_TENANTS, f, "ramp")
    for m in ("uniform", "ramp", "head"):
        specs[f"T{BASE_TENANTS}/f{BASE_OVERLAP:g}/{m}"] = (
            BASE_TENANTS,
            BASE_OVERLAP,
            m,
        )

    with Timer() as tm:
        for key, (t, f, m) in specs.items():
            sc = scenario(t, f, m)
            scenarios[key] = sc.to_dict()
            cells[key] = _cell(sc)
        # determinism probe: the base cell rerun must be bit-identical
        base_key = f"T{BASE_TENANTS}/f{BASE_OVERLAP:g}/ramp"
        rerun = _cell(scenario(BASE_TENANTS, BASE_OVERLAP, "ramp"))
    drop_wall = lambda c: {k: v for k, v in c.items() if k != "throughput_rps"}
    if drop_wall(rerun) != drop_wall(cells[base_key]):
        raise RuntimeError(
            "serving sweep is not bit-reproducible under a fixed seed"
        )

    overlap = cells[f"T{BASE_TENANTS}/f0.9/ramp"]["hit_ratio"]
    disjoint = cells[f"T{BASE_TENANTS}/f0/ramp"]["hit_ratio"]
    gain = overlap / max(disjoint, 1e-9)
    total_events = sum(c["n_block_events"] for c in cells.values())
    payload = {
        "preset": "serving_multitenant",
        "scenarios": scenarios,
        "sweep": cells,
        "hit_ratio_gain_overlap_vs_disjoint": gain,
        "base_cell": base_key,
        "n_total_block_events": total_events,
        "bitidentical_rerun": True,
    }
    save_artifact("serving", payload)

    print("# multi-tenant serving sweep (tenants x overlap x mix)")
    for key, c in cells.items():
        print(
            f"  {key:16s} hit={c['hit_ratio']:.3f} "
            f"active={c['tenants_active']}/{c['tenants_declared']} "
            f"overbook={c['overbooking_gain']:.2f} "
            f"flops_saved={c['prefill_flops_saved']:.3g} "
            f"p99={c['latency_p99_s']:.2e}s"
        )
    print(
        f"# object sharing raises the prefix hit ratio {gain:.2f}x "
        "(90%-shared vs disjoint prompt pools; Prop. 3.1 in serving form)"
    )
    csv_row(
        "serving",
        tm.seconds * 1e6 / max(total_events, 1),
        f"hit_gain={gain:.3f}",
    )
    return payload


if __name__ == "__main__":
    main()
