"""Serving-side benefit of object sharing (the framework-integration
benchmark): multi-tenant engine in accounting mode under overlapping vs
disjoint workloads — prefill FLOPs saved, sharing ratio, ripple overhead.

This is the paper's Prop. 3.1 economics transplanted to LLM serving:
shared prefix blocks are charged l/|P(n)|, so tenants with overlapping
demand effectively enlarge each other's caches.
"""

from __future__ import annotations

import numpy as np

from repro.cacheblocks import layout_for
from repro.configs import get_config
from repro.serving import EngineConfig, ServingEngine, TenantSpec

from .common import Timer, csv_row, quick_mode, save_artifact


def run_scenario(overlap: bool, n_requests: int = 600, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    cfg = get_config("qwen3-1.7b").reduced()
    ecfg = EngineConfig(block_tokens=8, pool_blocks=1024)
    layout = layout_for(cfg, block_tokens=8)
    pool_bytes = ecfg.pool_blocks * layout.bytes_per_block
    engine = ServingEngine(
        cfg,
        tenants=[
            TenantSpec("A", 0.30 * pool_bytes),
            TenantSpec("B", 0.30 * pool_bytes),
            TenantSpec("C", 0.30 * pool_bytes),
        ],
        engine_cfg=ecfg,
    )
    # popularity over prompt prefixes: Zipf like the paper's IRM
    n_prompts = 64
    ranks = np.arange(1, n_prompts + 1)
    p = ranks ** -1.0
    p /= p.sum()
    shared_prompts = [rng.integers(0, cfg.vocab_size, 64) for _ in range(n_prompts)]
    private = {
        t: [rng.integers(0, cfg.vocab_size, 64) for _ in range(n_prompts)]
        for t in ("A", "B", "C")
    }
    for _ in range(n_requests):
        t = rng.choice(["A", "B", "C"])
        idx = rng.choice(n_prompts, p=p)
        prompt = shared_prompts[idx] if overlap else private[t][idx]
        user = rng.integers(0, cfg.vocab_size, 16)
        engine.submit(t, np.concatenate([prompt, user]), max_new_tokens=0)
    return engine.stats()


def main() -> dict:
    n_requests = 120 if quick_mode() else 600
    with Timer() as tm:
        shared = run_scenario(overlap=True, n_requests=n_requests)
        disjoint = run_scenario(overlap=False, n_requests=n_requests)
    gain = (
        shared["prefix_hit_token_ratio"]
        / max(disjoint["prefix_hit_token_ratio"], 1e-9)
    )
    payload = {"overlapping": shared, "disjoint": disjoint,
               "hit_ratio_gain": gain}
    save_artifact("serving", payload)
    print("# multi-tenant serving: overlapping vs disjoint workloads")
    for name, s in (("overlapping", shared), ("disjoint", disjoint)):
        print(f"  {name:12s} hit_ratio={s['prefix_hit_token_ratio']:.3f} "
              f"sharing={s['sharing_ratio']:.2f} "
              f"ripple={s['ripple_evictions']} "
              f"flops_saved={s['flops_saved']:.3g}")
    print(f"# object sharing raises prefix hit ratio {gain:.2f}x under "
          f"overlapping demand (Prop 3.1 in serving form)")
    csv_row("serving", tm.seconds * 1e6 / (2 * n_requests), f"hit_gain={gain:.3f}")
    return payload


if __name__ == "__main__":
    main()
