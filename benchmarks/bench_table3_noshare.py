"""Paper Table III: the not-shared baseline at b=(64,64,8), plus the
Prop. 3.1 dominance check (sharing >= not-shared per proxy, per object).

Simulates J independent LRUs on the identical request trace used for the
shared system, reports hit probabilities at ranks 1/10/100/1000, and
verifies that the shared system's per-object occupancy dominates the
not-shared one everywhere (the coupling argument of Prop. 3.1).

Both systems run on the array engine (``variant="noshare"`` is the exact
fast port of :class:`repro.core.baselines.NotSharedSystem` — see
``tests/test_fastsim.py``).
"""

from __future__ import annotations

import numpy as np

from repro.core import SimParams, rate_matrix, sample_trace, simulate_trace

from .common import (
    ALPHAS,
    B_PHYSICAL,
    N_OBJECTS,
    RANKS,
    TABLE3,
    Timer,
    csv_row,
    mean_rel_err,
    save_artifact,
    table1_requests,
)


def main() -> dict:
    b = (64, 64, 8)
    n_requests = table1_requests()
    lam = rate_matrix(N_OBJECTS, list(ALPHAS))
    trace = sample_trace(lam, n_requests, seed=11)
    warmup = max(n_requests // 15, 1000)

    with Timer() as tm:
        h_ns = simulate_trace(
            SimParams(allocations=b, variant="noshare"),
            trace,
            N_OBJECTS,
            warmup=warmup,
        ).occupancy
        h_sh = simulate_trace(
            SimParams(allocations=b, physical_capacity=B_PHYSICAL),
            trace,
            N_OBJECTS,
            warmup=warmup,
        ).occupancy

    rows, all_pred, all_ref = {}, [], []
    for i in range(3):
        pred = [float(h_ns[i, k - 1]) for k in RANKS]
        ref = TABLE3[b][i]
        rows[i] = {"sim_notshared": pred, "paper": ref,
                   "sim_shared": [float(h_sh[i, k - 1]) for k in RANKS]}
        all_pred += pred
        all_ref += ref
    err = mean_rel_err(all_pred, all_ref)

    # Prop 3.1: shared dominates not-shared. Allow tiny trajectory noise
    # on near-zero tail entries.
    diff = h_sh - h_ns
    tol = 0.01 + 0.05 * h_ns
    prop31_ok = bool(np.all(diff >= -tol))
    prop31_margin = float(diff.min())

    payload = {
        "b": b,
        "rows": rows,
        "mean_rel_err_vs_paper": err,
        "prop31_dominance_ok": prop31_ok,
        "prop31_worst_margin": prop31_margin,
        "mean_gain_sharing": float(diff.mean()),
        "engine": "fastsim",
    }
    save_artifact("table3_noshare", payload)

    print(f"# Table III reproduction (not-shared, b={b})")
    print("# i   h_1      h_10     h_100    h_1000   (paper in parens)")
    for i in range(3):
        cells = "  ".join(
            f"{p:.4f}({r:.4f})"
            for p, r in zip(rows[i]["sim_notshared"], rows[i]["paper"])
        )
        print(f"  {i}  {cells}")
    print(f"# Prop 3.1 dominance (shared >= not-shared): {prop31_ok} "
          f"(worst margin {prop31_margin:+.4f})")
    csv_row(
        "table3_noshare",
        tm.seconds * 1e6 / (2 * n_requests),
        f"mean_rel_err={err:.4f};prop31_ok={prop31_ok}",
    )
    return payload


if __name__ == "__main__":
    main()
