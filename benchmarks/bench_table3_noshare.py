"""Paper Table III: the not-shared baseline at b=(64,64,8), plus the
Prop. 3.1 dominance check (sharing >= not-shared per proxy, per object).

Simulates J independent LRUs on the identical request trace used for the
shared system, reports hit probabilities at ranks 1/10/100/1000, and
verifies that the shared system's per-object occupancy dominates the
not-shared one everywhere (the coupling argument of Prop. 3.1).
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    GetResult,
    NotSharedSystem,
    SharedLRUCache,
    rate_matrix,
    sample_trace,
)
from repro.core.metrics import OccupancyRecorder

from .common import (
    ALPHAS,
    B_PHYSICAL,
    N_OBJECTS,
    RANKS,
    TABLE3,
    Timer,
    csv_row,
    mean_rel_err,
    save_artifact,
    table1_requests,
)


class _NotSharedOccupancy:
    """Residence-time occupancy for the J independent LRUs."""

    def __init__(self, J: int, N: int) -> None:
        self.rec = OccupancyRecorder(J, N)

    def run(self, system: NotSharedSystem, proxies, objects) -> np.ndarray:
        n = len(proxies)
        warmup = max(n // 15, 1000)
        P, O = proxies.tolist(), objects.tolist()
        for idx in range(n):
            self.rec.now = idx
            if idx == warmup:
                self.rec.reset_window()
            i, k = P[idx], O[idx]
            st = system.get_autofetch(i, k, 1)
            if st.result is GetResult.MISS:
                self.rec.hook("attach", i, k)
            for ev in st.evictions:
                self.rec.hook("detach", ev.proxy, ev.key)
        self.rec.now = n
        self.rec.finalize()
        return self.rec.occupancy()


def main() -> dict:
    b = (64, 64, 8)
    n_requests = table1_requests()
    lam = rate_matrix(N_OBJECTS, list(ALPHAS))
    trace = sample_trace(lam, n_requests, seed=11)

    with Timer() as tm:
        ns = NotSharedSystem(list(b))
        h_ns = _NotSharedOccupancy(3, N_OBJECTS).run(ns, trace.proxies, trace.objects)

        shared = SharedLRUCache(list(b), physical_capacity=B_PHYSICAL)
        rec = OccupancyRecorder(3, N_OBJECTS).attach_to(shared)
        warmup = max(n_requests // 15, 1000)
        P, O = trace.proxies.tolist(), trace.objects.tolist()
        for idx in range(n_requests):
            rec.now = idx
            if idx == warmup:
                rec.reset_window()
            i, k = P[idx], O[idx]
            if shared.get(i, k).result is GetResult.MISS:
                shared.set(i, k, 1)
        rec.now = n_requests
        rec.finalize()
        h_sh = rec.occupancy()

    rows, all_pred, all_ref = {}, [], []
    for i in range(3):
        pred = [float(h_ns[i, k - 1]) for k in RANKS]
        ref = TABLE3[b][i]
        rows[i] = {"sim_notshared": pred, "paper": ref,
                   "sim_shared": [float(h_sh[i, k - 1]) for k in RANKS]}
        all_pred += pred
        all_ref += ref
    err = mean_rel_err(all_pred, all_ref)

    # Prop 3.1: shared dominates not-shared. Allow tiny trajectory noise
    # on near-zero tail entries.
    diff = h_sh - h_ns
    tol = 0.01 + 0.05 * h_ns
    prop31_ok = bool(np.all(diff >= -tol))
    prop31_margin = float(diff.min())

    payload = {
        "b": b,
        "rows": rows,
        "mean_rel_err_vs_paper": err,
        "prop31_dominance_ok": prop31_ok,
        "prop31_worst_margin": prop31_margin,
        "mean_gain_sharing": float(diff.mean()),
    }
    save_artifact("table3_noshare", payload)

    print(f"# Table III reproduction (not-shared, b={b})")
    print("# i   h_1      h_10     h_100    h_1000   (paper in parens)")
    for i in range(3):
        cells = "  ".join(
            f"{p:.4f}({r:.4f})"
            for p, r in zip(rows[i]["sim_notshared"], rows[i]["paper"])
        )
        print(f"  {i}  {cells}")
    print(f"# Prop 3.1 dominance (shared >= not-shared): {prop31_ok} "
          f"(worst margin {prop31_margin:+.4f})")
    csv_row(
        "table3_noshare",
        tm.seconds * 1e6 / (2 * n_requests),
        f"mean_rel_err={err:.4f};prop31_ok={prop31_ok}",
    )
    return payload


if __name__ == "__main__":
    main()
