"""Paper Table III: the not-shared baseline at b=(64,64,8), plus the
Prop. 3.1 dominance check (sharing >= not-shared per proxy, per object).

Runs the ``table3_noshare`` preset (J independent LRUs) and the
``table1`` preset at the same allocations **on the same seed** — the
scenario layer guarantees both see the identical request trace — then
verifies that the shared system's per-object occupancy dominates the
not-shared one everywhere (the coupling argument of Prop. 3.1).
"""

from __future__ import annotations

import numpy as np

from repro.scenario import get_preset

from .common import (
    RANKS,
    TABLE3,
    Timer,
    csv_row,
    mean_rel_err,
    save_artifact,
    section5_scale,
)


def main() -> dict:
    b = (64, 64, 8)
    scale = section5_scale()
    ns_sc = get_preset("table3_noshare", b=b).scaled(*scale)
    # Same workload + same seed -> bit-identical trace for the shared run.
    sh_sc = get_preset("table1", b=b, seed=ns_sc.seed).scaled(*scale)

    with Timer() as tm:
        ns = ns_sc.run()
        sh = sh_sc.run()
    # densify: at REPRO_FULL the runs auto-stream and carry sparse
    # occupancy; the Prop-3.1 check needs elementwise (J, N) math (N=1000)
    h_ns, h_sh = ns.dense_hit_prob(), sh.dense_hit_prob()

    rows, all_pred, all_ref = {}, [], []
    for i in range(3):
        pred = ns.hit_prob_at_ranks(i, RANKS)
        ref = TABLE3[b][i]
        rows[i] = {"sim_notshared": pred, "paper": ref,
                   "sim_shared": sh.hit_prob_at_ranks(i, RANKS)}
        all_pred += pred
        all_ref += ref
    err = mean_rel_err(all_pred, all_ref)

    # Prop 3.1: shared dominates not-shared. Allow tiny trajectory noise
    # on near-zero tail entries.
    diff = h_sh - h_ns
    tol = 0.01 + 0.05 * h_ns
    prop31_ok = bool(np.all(diff >= -tol))
    prop31_margin = float(diff.min())

    payload = {
        "preset": "table3_noshare",
        "scenarios": {"noshare": ns_sc.to_dict(), "shared": sh_sc.to_dict()},
        "b": b,
        "rows": rows,
        "mean_rel_err_vs_paper": err,
        "prop31_dominance_ok": prop31_ok,
        "prop31_worst_margin": prop31_margin,
        "mean_gain_sharing": float(diff.mean()),
        "engine": ns.backend,
    }
    save_artifact("table3_noshare", payload)

    print(f"# Table III reproduction (not-shared, b={b})")
    print("# i   h_1      h_10     h_100    h_1000   (paper in parens)")
    for i in range(3):
        cells = "  ".join(
            f"{p:.4f}({r:.4f})"
            for p, r in zip(rows[i]["sim_notshared"], rows[i]["paper"])
        )
        print(f"  {i}  {cells}")
    print(f"# Prop 3.1 dominance (shared >= not-shared): {prop31_ok} "
          f"(worst margin {prop31_margin:+.4f})")
    csv_row(
        "table3_noshare",
        tm.seconds * 1e6 / (2 * ns_sc.n_requests),
        f"mean_rel_err={err:.4f};prop31_ok={prop31_ok}",
    )
    return payload


if __name__ == "__main__":
    main()
