"""Section IV-C: overbooking + admission control.

Quantifies the headline economics of object sharing and validates them
end to end:

1. **Overbooking-gain sweep** — how much SLA memory (``sum b_i*``) one
   unit of virtual commitment (``sum b_i``) serves, swept over the
   number of tenants J, the tenants' Zipf alpha, and the SLA allocation
   b* (the capacity axis: b*/N is what matters for the working-set
   occupancies).
2. **Online episode** — the ``admission_overbooking`` scenario preset:
   tenants arrive/depart through the eq. (13) controller, eq. (10)
   virtual allocations are refreshed from online popularity estimates,
   and the final admitted set is *simulated* at its virtual allocations
   so the artifact records realized vs predicted SLA hit probabilities
   (they must agree within Monte-Carlo + approximation noise — that is
   the paper's admission-control promise).
"""

from __future__ import annotations

import numpy as np

from repro.core import virtual_allocations
from repro.scenario import Workload, get_preset

from .common import (
    FULL,
    N_OBJECTS,
    Timer,
    csv_row,
    quick_mode,
    save_artifact,
    section5_scale,
)


def _tenant_rates(alphas):
    """Tenant demand via the scenario Workload axis (same IRM/Zipf
    definition the presets use)."""
    return Workload(
        kind="irm", n_objects=N_OBJECTS, alphas=tuple(alphas)
    ).rates()


def _alphas(base: float, J: int):
    """Similar-but-not-identical tenants (high overlap = strong
    sharing, the regime Section IV-C targets)."""
    return [base + 0.02 * i for i in range(J)]


def overbooking_sweep() -> dict:
    """Overbooking factor ``J*b* / sum b_virtual`` over (J, alpha, b*)."""
    lengths = np.ones(N_OBJECTS)
    sweep: dict = {}
    J_grid = (2, 3, 4, 6, 8)
    alpha_grid = (0.7, 0.9, 1.1) if not quick_mode() else (0.9,)
    b_grid = (32.0, 64.0, 128.0) if not quick_mode() else (64.0,)
    for J in J_grid:
        for alpha in alpha_grid:
            lam = _tenant_rates(_alphas(alpha, J))
            for b_star in b_grid:
                b, _ = virtual_allocations(lam, lengths, np.full(J, b_star))
                sweep[f"J={J},alpha={alpha},b*={b_star:.0f}"] = {
                    "J": J,
                    "alpha": alpha,
                    "b_star": b_star,
                    "sum_b_star": J * b_star,
                    "sum_b_virtual": float(b.sum()),
                    "overbooking_factor": float(J * b_star / b.sum()),
                }
    return sweep


def main() -> dict:
    req, _ = section5_scale()
    with Timer() as tm:
        sweep = overbooking_sweep()

        # Online episode at harness scale; the preset is paper scale.
        sc = get_preset("admission_overbooking").scaled(requests=req)
        rep = sc.run()
        episode = rep.extras["admission"]

    payload = {
        "preset": "admission_overbooking",
        "scenario": sc.to_dict(),
        "overbooking_sweep": sweep,
        "episode": episode,
        "n_validation_requests": rep.n_requests,
        "validation_backend": rep.backend,
        "full_scale": FULL,
    }
    save_artifact("admission", payload)

    print("# Overbooking factor sweep (gain = sum b* / sum b_virtual)")
    for key, f in sweep.items():
        print(f"  {key}: factor={f['overbooking_factor']:.3f}")
    n_active = len(episode["active_tenants"])
    n_static = int(episode["capacity"] // max(episode["b_star"].values()))
    print(
        f"# Episode at B={episode['capacity']:.0f}: {n_active} tenants "
        f"active (static partitioning fits {n_static}); overbooked="
        f"{episode['overbooked']}, gain={episode['overbooking_gain']:.3f}"
    )
    print(
        f"# SLA check: max |realized - predicted| = "
        f"{episode['max_abs_sla_gap']:.4f} over {rep.n_requests:,} "
        f"validation requests"
    )
    csv_row(
        "admission",
        tm.seconds * 1e6 / max(len(sweep), 1),
        f"active={n_active}_vs_{n_static};gain="
        f"{episode['overbooking_gain']:.3f};sla_gap="
        f"{episode['max_abs_sla_gap']:.4f}",
    )
    return payload


if __name__ == "__main__":
    main()
