"""Section IV-C: overbooking + admission control.

Quantifies the headline economics of object sharing: how much SLA memory
(sum b_i*) the operator can sell against a fixed physical cache B when
virtual allocations are computed with the working-set approximation, and
how many tenants the eq. (13) conservative rule admits vs a no-sharing
operator.
"""

from __future__ import annotations

import numpy as np

from repro.core import AdmissionController, virtual_allocations
from repro.scenario import Workload

from .common import N_OBJECTS, Timer, csv_row, save_artifact


def _tenant_rates(alphas):
    """Tenant demand via the scenario Workload axis (same IRM/Zipf
    definition the presets use)."""
    return Workload(
        kind="irm", n_objects=N_OBJECTS, alphas=tuple(alphas)
    ).rates()


def main() -> dict:
    lengths = np.ones(N_OBJECTS)
    # A growing population of similar-but-not-identical tenants (similar
    # demand = high overlap = strong sharing, the regime Section IV-C
    # targets).
    alphas = [0.9 + 0.02 * i for i in range(10)]
    b_star = 64.0

    with Timer() as tm:
        # Overbooking factor as tenants join: virtual b for J tenants.
        factors = {}
        for J in (2, 3, 4, 6, 8):
            lam = _tenant_rates(alphas[:J])
            b, _ = virtual_allocations(lam, lengths, np.full(J, b_star))
            factors[J] = {
                "sum_b_star": J * b_star,
                "sum_b_virtual": float(b.sum()),
                "overbooking_factor": float(J * b_star / b.sum()),
                "b_virtual": b.tolist(),
            }

        # Admission episode: B sized for 6 unshared tenants; how many can
        # a sharing operator admit with eq. (13) + refresh?
        B = 6 * b_star
        ctl = AdmissionController(B, lengths)
        admitted = []
        for j in range(10):
            d = ctl.admit(f"tenant{j}", b_star)
            if not d.admitted:
                ctl.refresh()
                d = ctl.admit(f"tenant{j}", b_star)
            if d.admitted:
                admitted.append(j)
                lam = _tenant_rates(alphas[: len(admitted)])
                for idx, name in enumerate(f"tenant{a}" for a in admitted):
                    ctl.observe(name, lam[idx])
                ctl.refresh()
        n_sharing = len(admitted)
        n_unshared = int(B // b_star)

    payload = {
        "b_star": b_star,
        "B": B,
        "overbooking": factors,
        "admitted_with_sharing": n_sharing,
        "admitted_without_sharing": n_unshared,
        "final_committed_virtual": ctl.committed,
        "final_committed_sla": ctl.committed_sla,
        "overbooked": ctl.overbooked,
    }
    save_artifact("admission", payload)

    print("# Overbooking factor vs number of tenants (b*=64 each)")
    for J, f in factors.items():
        print(f"  J={J}: sum b*={f['sum_b_star']:.0f}  sum b={f['sum_b_virtual']:.1f}"
              f"  factor={f['overbooking_factor']:.3f}")
    print(f"# Admission at B={B:.0f}: sharing admits {n_sharing} tenants, "
          f"static partitioning admits {n_unshared}; overbooked={ctl.overbooked}")
    csv_row(
        "admission",
        tm.seconds * 1e6 / max(len(factors), 1),
        f"admitted={n_sharing}_vs_{n_unshared};factor_J8="
        f"{factors[8]['overbooking_factor']:.3f}",
    )
    return payload


if __name__ == "__main__":
    main()
