"""Monte-Carlo engine throughput: reference vs array engine (fastsim).

Measures requests/sec of:

* ``reference`` — the executable spec: ``SharedLRUCache`` driven one
  request at a time with an attached ``OccupancyRecorder`` (exactly how
  ``bench_table1_sim`` ran before the array engine existed);
* ``fastsim-flat`` — the allocation-free inlined Python loop over the
  struct-of-arrays state;
* ``fastsim`` — the auto backend (native C loop when a compiler is
  available, else the Python loop).

Workloads: the Table-I grid (J=3, N=1000, b in {8,64}^3, the paper's
Section V setup) and the reduced Fig.-2 / Section VI-C workload (J=9).
The estimators are bit-identical across engines (asserted in
``tests/test_fastsim.py``), so the speedup is free: same trajectory,
same occupancy integers, same Table-I numbers.

The reference loop is timed on a capped sub-trace (it is the slow thing
being replaced); the fast engines run the full trace.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import GetResult, SharedLRUCache, fastsim_c
from repro.core.fastsim import default_warmup, simulate_trace
from repro.core.irm import IRMTrace
from repro.core.metrics import OccupancyRecorder
from repro.scenario import get_preset

from .common import (
    B_GRID,
    FULL,
    Timer,
    csv_row,
    fig2_scale_factors,
    quick_mode,
    save_artifact,
    section5_scale,
)


def reference_run(b, B, trace, n_objects, warmup) -> float:
    """Drive the reference engine exactly as the old bench_table1_sim."""
    cache = SharedLRUCache(list(b), physical_capacity=B)
    rec = OccupancyRecorder(len(b), n_objects).attach_to(cache)
    P, O = trace.proxies.tolist(), trace.objects.tolist()
    t0 = time.perf_counter()
    for idx in range(len(P)):
        rec.now = idx
        if idx == warmup:
            rec.reset_window()
        i, k = P[idx], O[idx]
        if cache.get(i, k).result is GetResult.MISS:
            cache.set(i, k, 1)
    rec.now = len(P)
    rec.finalize()
    return time.perf_counter() - t0


def _sub(trace, n):
    return IRMTrace(trace.proxies[:n], trace.objects[:n])


def bench_workload(name, scenarios, ref_cap):
    """Race the reference loop against the fastsim backends on the
    workload/system of each scenario (presets supply both)."""
    rows = {}
    tot = {"reference": [0, 0.0], "fastsim-flat": [0, 0.0], "fastsim": [0, 0.0]}
    for ci, sc in enumerate(scenarios):
        b = sc.system.allocations
        B = sc.system.capacity()
        n_objects = sc.workload.n_objects
        n_requests = sc.n_requests
        trace = sc.workload.sample(n_requests, seed=sc.seed + ci)
        warmup = default_warmup(n_requests, b)
        params = sc.system.to_sim_params()

        n_ref = min(n_requests, ref_cap)
        ref_s = reference_run(b, B, _sub(trace, n_ref), n_objects,
                              min(warmup, n_ref // 2))
        res_flat = simulate_trace(params, trace, n_objects, warmup=warmup,
                                  engine="flat")
        res_auto = simulate_trace(params, trace, n_objects, warmup=warmup)

        rows[str(tuple(b))] = {
            "reference_rps": n_ref / ref_s,
            "fastsim_flat_rps": res_flat.requests_per_sec,
            "fastsim_rps": res_auto.requests_per_sec,
        }
        tot["reference"][0] += n_ref
        tot["reference"][1] += ref_s
        tot["fastsim-flat"][0] += n_requests
        tot["fastsim-flat"][1] += res_flat.elapsed_s
        tot["fastsim"][0] += n_requests
        tot["fastsim"][1] += res_auto.elapsed_s

    agg = {k: n / max(s, 1e-12) for k, (n, s) in tot.items()}
    return {
        "workload": name,
        "n_requests_per_combo": n_requests,
        "reference_requests_per_combo": min(n_requests, ref_cap),
        "combos": rows,
        "requests_per_sec": agg,
        "speedup_auto_vs_reference": agg["fastsim"] / agg["reference"],
        "speedup_flat_vs_reference": agg["fastsim-flat"] / agg["reference"],
        "c_backend_available": fastsim_c.available(),
    }


def main() -> dict:
    quick = quick_mode()
    ref_cap = 20_000 if quick else (200_000 if not FULL else 400_000)
    t1_combos = B_GRID[:2] if quick else B_GRID

    with Timer() as tm:
        t1 = bench_workload(
            "table1",
            [get_preset("table1", b=b).scaled(*section5_scale())
             for b in t1_combos],
            ref_cap,
        )
        req_f, cat_f = fig2_scale_factors()
        f2_sc = get_preset("fig2_ripple").scaled(req_f / 3, cat_f)
        f2 = bench_workload("fig2_reduced", [f2_sc], ref_cap)

    payload = {
        "table1": t1,
        "fig2": f2,
        "estimator_note": (
            "occupancy/hit statistics are bit-identical across engines on "
            "the same trace (tests/test_fastsim.py), so Table-I accuracy "
            "is unchanged by construction"
        ),
        "elapsed_s": tm.seconds,
    }
    save_artifact("simthroughput", payload)

    print("# Monte-Carlo engine throughput (requests/sec)")
    for wl in (t1, f2):
        agg = wl["requests_per_sec"]
        print(
            f"  {wl['workload']:13s} reference={agg['reference']:>12,.0f}  "
            f"flat={agg['fastsim-flat']:>12,.0f}  "
            f"auto={agg['fastsim']:>14,.0f}  "
            f"speedup={wl['speedup_auto_vs_reference']:.1f}x"
        )
    t1_speed = t1["speedup_auto_vs_reference"]
    csv_row(
        "sim_throughput_table1",
        1e6 / t1["requests_per_sec"]["fastsim"],
        f"speedup_vs_reference={t1_speed:.1f}x",
    )
    csv_row(
        "sim_throughput_fig2",
        1e6 / f2["requests_per_sec"]["fastsim"],
        f"speedup_vs_reference={f2['speedup_auto_vs_reference']:.1f}x",
    )
    return payload


if __name__ == "__main__":
    main()
