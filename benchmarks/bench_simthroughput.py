"""Monte-Carlo engine throughput: reference vs array engine (fastsim).

Measures requests/sec of:

* ``reference`` — the executable spec: ``SharedLRUCache`` driven one
  request at a time with an attached ``OccupancyRecorder`` (exactly how
  ``bench_table1_sim`` ran before the array engine existed);
* ``fastsim-flat`` — the allocation-free inlined Python loop over the
  struct-of-arrays state;
* ``fastsim`` — the auto backend (native C loop when a compiler is
  available, else the Python loop).

Workloads: the Table-I grid (J=3, N=1000, b in {8,64}^3, the paper's
Section V setup) and the reduced Fig.-2 / Section VI-C workload (J=9).
The estimators are bit-identical across engines (asserted in
``tests/test_fastsim.py``), so the speedup is free: same trajectory,
same occupancy integers, same Table-I numbers.

The reference loop is timed on a capped sub-trace (it is the slow thing
being replaced); the fast engines run the full trace.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import GetResult, SharedLRUCache, fastsim_c
from repro.core.fastsim import default_warmup, simulate_trace
from repro.core.irm import IRMTrace
from repro.core.metrics import OccupancyRecorder
from repro.scenario import get_preset

from .common import (
    B_GRID,
    FULL,
    Timer,
    csv_row,
    fig2_scale_factors,
    quick_mode,
    save_artifact,
    section5_scale,
)


def reference_run(b, B, trace, n_objects, warmup) -> float:
    """Drive the reference engine exactly as the old bench_table1_sim."""
    cache = SharedLRUCache(list(b), physical_capacity=B)
    rec = OccupancyRecorder(len(b), n_objects).attach_to(cache)
    P, O = trace.proxies.tolist(), trace.objects.tolist()
    t0 = time.perf_counter()
    for idx in range(len(P)):
        rec.now = idx
        if idx == warmup:
            rec.reset_window()
        i, k = P[idx], O[idx]
        if cache.get(i, k).result is GetResult.MISS:
            cache.set(i, k, 1)
    rec.now = len(P)
    rec.finalize()
    return time.perf_counter() - t0


def _sub(trace, n):
    return IRMTrace(trace.proxies[:n], trace.objects[:n])


def bench_workload(name, scenarios, ref_cap):
    """Race the reference loop against the fastsim backends on the
    workload/system of each scenario (presets supply both)."""
    rows = {}
    tot = {"reference": [0, 0.0], "fastsim-flat": [0, 0.0], "fastsim": [0, 0.0]}
    for ci, sc in enumerate(scenarios):
        b = sc.system.allocations
        B = sc.system.capacity()
        n_objects = sc.workload.n_objects
        n_requests = sc.n_requests
        trace = sc.workload.sample(n_requests, seed=sc.seed + ci)
        warmup = default_warmup(n_requests, b)
        params = sc.system.to_sim_params()

        n_ref = min(n_requests, ref_cap)
        ref_s = reference_run(b, B, _sub(trace, n_ref), n_objects,
                              min(warmup, n_ref // 2))
        res_flat = simulate_trace(params, trace, n_objects, warmup=warmup,
                                  engine="flat")
        res_auto = simulate_trace(params, trace, n_objects, warmup=warmup)

        rows[str(tuple(b))] = {
            "reference_rps": n_ref / ref_s,
            "fastsim_flat_rps": res_flat.requests_per_sec,
            "fastsim_rps": res_auto.requests_per_sec,
        }
        tot["reference"][0] += n_ref
        tot["reference"][1] += ref_s
        tot["fastsim-flat"][0] += n_requests
        tot["fastsim-flat"][1] += res_flat.elapsed_s
        tot["fastsim"][0] += n_requests
        tot["fastsim"][1] += res_auto.elapsed_s

    agg = {k: n / max(s, 1e-12) for k, (n, s) in tot.items()}
    return {
        "workload": name,
        "n_requests_per_combo": n_requests,
        "reference_requests_per_combo": min(n_requests, ref_cap),
        "combos": rows,
        "requests_per_sec": agg,
        "speedup_auto_vs_reference": agg["fastsim"] / agg["reference"],
        "speedup_flat_vs_reference": agg["fastsim-flat"] / agg["reference"],
        "c_backend_available": fastsim_c.available(),
    }


def bench_xla_ensemble(quick: bool) -> dict:
    """Batched R-replica XLA ensemble vs R sequential single-replica
    XLA runs (the ISSUE-5 acceptance measurement).

    Both sides exclude compilation (the chunk runners AOT-compile
    outside the timed region) and replica 0 of the batch is asserted
    bit-identical to the single-run driver — the benchmark *fails* if
    the ensemble ever drifts from the reference trajectory.
    """
    from repro.core.fastsim import default_warmup, simulate_trace
    from repro.core.fastsim_jax import simulate_ensemble
    from repro.scenario.runner import derive_seeds, ensemble_seeds

    R = 8
    sc = get_preset("table1", b=(64, 64, 64)).scaled(
        0.004 if quick else (0.02 if not FULL else 0.05), 1.0
    )
    n = sc.n_requests
    params = sc.system.to_sim_params()
    N = sc.workload.n_objects
    warmup = default_warmup(n, sc.system.allocations)
    trace_seed, _ = derive_seeds(sc.seed)
    traces = [
        sc.workload.sample(n, s) for s in ensemble_seeds(trace_seed, R)
    ]

    # no warm-up pass needed: the runners AOT-compile outside the timed
    # region (elapsed provably excludes compilation — see
    # tests/test_ensemble.py::test_chunk_runner_compiles_once...), and
    # the global executable cache makes the 8 sequential runs compile
    # once, not eight times
    singles = [
        simulate_trace(params, t, N, warmup=warmup, engine="xla")
        for t in traces
    ]
    seq_s = sum(r.elapsed_s for r in singles)
    ens = simulate_ensemble(params, traces, N, warmup=warmup)
    bat_s = ens[0].elapsed_s

    r0, s0 = ens[0], singles[0]
    identical = bool(
        np.array_equal(r0.dense_occupancy(), s0.dense_occupancy())
        and np.array_equal(r0.final_vlen, s0.final_vlen)
        and np.array_equal(r0.evictions_per_set, s0.evictions_per_set)
        and (r0.n_hit_list, r0.n_hit_cache, r0.n_miss)
        == (s0.n_hit_list, s0.n_hit_cache, s0.n_miss)
    )
    if not identical:
        raise AssertionError(
            "batched XLA ensemble replica 0 diverged from the "
            "single-run driver"
        )
    return {
        "replications": R,
        "n_requests_per_replica": n,
        "sequential_elapsed_s": seq_s,
        "batched_elapsed_s": bat_s,
        "sequential_rps": R * n / max(seq_s, 1e-12),
        "batched_rps": R * n / max(bat_s, 1e-12),
        "speedup_batched_vs_sequential": seq_s / max(bat_s, 1e-12),
        "replica0_bitidentical": identical,
        "note": (
            "both sides AOT-compile outside the timed region; on this "
            "CPU the per-update cost of XLA scatters grows with the "
            "lane count, so the batched win is bounded here — the "
            "batched driver's payoff on CPU is one compile + one "
            "dispatch for the whole ensemble, and the formulation "
            "targets accelerator backends where lane updates vectorize"
        ),
    }


def main() -> dict:
    quick = quick_mode()
    ref_cap = 20_000 if quick else (200_000 if not FULL else 400_000)
    t1_combos = B_GRID[:2] if quick else B_GRID

    with Timer() as tm:
        t1 = bench_workload(
            "table1",
            [get_preset("table1", b=b).scaled(*section5_scale())
             for b in t1_combos],
            ref_cap,
        )
        req_f, cat_f = fig2_scale_factors()
        f2_sc = get_preset("fig2_ripple").scaled(req_f / 3, cat_f)
        f2 = bench_workload("fig2_reduced", [f2_sc], ref_cap)
        xe = bench_xla_ensemble(quick)

    payload = {
        "table1": t1,
        "fig2": f2,
        "xla_ensemble": xe,
        "estimator_note": (
            "occupancy/hit statistics are bit-identical across engines on "
            "the same trace (tests/test_fastsim.py), so Table-I accuracy "
            "is unchanged by construction"
        ),
        "elapsed_s": tm.seconds,
    }
    save_artifact("simthroughput", payload)

    print("# Monte-Carlo engine throughput (requests/sec)")
    for wl in (t1, f2):
        agg = wl["requests_per_sec"]
        print(
            f"  {wl['workload']:13s} reference={agg['reference']:>12,.0f}  "
            f"flat={agg['fastsim-flat']:>12,.0f}  "
            f"auto={agg['fastsim']:>14,.0f}  "
            f"speedup={wl['speedup_auto_vs_reference']:.1f}x"
        )
    print(
        f"  xla ensemble  R={xe['replications']} batched "
        f"{xe['batched_rps']:>12,.0f} req/s vs sequential "
        f"{xe['sequential_rps']:>12,.0f} — "
        f"{xe['speedup_batched_vs_sequential']:.2f}x, replica-0 "
        f"bit-identical: {xe['replica0_bitidentical']}"
    )
    t1_speed = t1["speedup_auto_vs_reference"]
    csv_row(
        "sim_throughput_table1",
        1e6 / t1["requests_per_sec"]["fastsim"],
        f"speedup_vs_reference={t1_speed:.1f}x",
    )
    csv_row(
        "sim_throughput_fig2",
        1e6 / f2["requests_per_sec"]["fastsim"],
        f"speedup_vs_reference={f2['speedup_auto_vs_reference']:.1f}x",
    )
    return payload


if __name__ == "__main__":
    main()
