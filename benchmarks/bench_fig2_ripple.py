"""Paper Fig. 2 + Table V: ripple-eviction histogram and set overhead.

Section VI-C workload: J=9 very different proxies (Zipf 0.5+0.5(i-1)),
1e6 items of 100 kB, 3 GB cache, allocations 3x100 MB + 3x200 MB +
3x700 MB — the ``fig2_ripple`` preset, scaled 10x down by default
(REPRO_FULL=1 for paper scale).

Reported:
* histogram of evictions per set under MCD-OS (paper: max ~9-10, only
  16 % of sets ripple beyond one eviction) — from the scenario run's
  ripple statistics;
* mean/std set execution times for MCD-OS vs plain MCD with one pooled
  LRU of the same collective size (paper Table V: 474 vs 412 us — the
  *ratio*, ~1.15x, is the implementation-independent claim). Wall-clock
  per-command timing is inherently about the reference server objects,
  so that part still drives ``MCDOSServer``/``MCDServer`` directly, on a
  capped sub-trace drawn from the same preset workload.
"""

from __future__ import annotations

import numpy as np

from repro.core import GetResult, MCDOSServer, MCDServer
from repro.scenario import get_preset

from .common import Timer, csv_row, fig2_scale_factors, save_artifact

# Wall-clock Table-V timing drives the reference servers per request;
# cap that part so the benchmark stays dominated by the fast engine.
LATENCY_MAX_REQUESTS = 150_000


def drive(server, proxies, objects, warmup: int) -> None:
    P, O = proxies.tolist(), objects.tolist()
    n = len(P)
    for idx in range(n):
        if idx == warmup:
            from repro.core.metrics import LatencyRecorder, RippleStats

            server.stats.ripple = RippleStats()
            server.stats.latency = LatencyRecorder()
        i, k = P[idx], O[idx]
        if server.get(i, k).result is GetResult.MISS:
            server.set(i, k, 1)  # 1 unit = 100 kB


def main() -> dict:
    sc = get_preset("fig2_ripple").scaled(*fig2_scale_factors())
    b = tuple(sc.system.allocations)
    n_objects = sc.workload.n_objects
    B = sc.system.capacity()
    n_requests = sc.n_requests

    # ---- Fig. 2: evictions-per-set histogram via the scenario run ----
    with Timer() as tm:
        rep = sc.run()
    hist = {int(k): v for k, v in rep.ripple["evictions_per_set"].items()}
    frac_multi = rep.ripple["frac_multi_eviction"]

    # ---- Table V: per-set wall clock on the reference servers --------
    n_lat = min(n_requests, LATENCY_MAX_REQUESTS)
    lat_trace = sc.workload.sample(n_lat, seed=sc.seed + 1)
    lat_warmup = n_lat // 10
    mcdos = MCDOSServer(list(b), B, n_objects_hint=1)
    drive(mcdos, lat_trace.proxies, lat_trace.objects, lat_warmup)
    mcd = MCDServer(B, len(b), n_objects_hint=1)
    drive(mcd, lat_trace.proxies, lat_trace.objects, lat_warmup)
    os_mean, os_std, os_n = mcdos.stats.latency.summary("set")
    mc_mean, mc_std, mc_n = mcd.stats.latency.summary("set")

    payload = {
        "preset": "fig2_ripple",
        "scenario": sc.to_dict(),
        "allocations": list(b),
        "n_objects": n_objects,
        "B": B,
        "n_requests": n_requests,
        "engine": rep.backend,
        "engine_requests_per_sec": rep.throughput_rps,
        "evictions_per_set_histogram": hist,
        "frac_multi_eviction": frac_multi,
        "paper_frac_multi_eviction": 0.16,
        "max_ripple": max((k for k, v in hist.items() if v), default=0),
        "set_us": {
            "n_requests_timed": n_lat,
            "mcd_os": {"mean": os_mean, "std": os_std, "n": os_n},
            "mcd": {"mean": mc_mean, "std": mc_std, "n": mc_n},
            "overhead_ratio": os_mean / mc_mean if mc_mean > 0 else float("nan"),
            "paper": {"mcd_os": {"mean": 474, "std": 127},
                      "mcd": {"mean": 412, "std": 111},
                      "overhead_ratio": 474 / 412},
        },
    }
    save_artifact("fig2_ripple", payload)

    print(f"# Fig. 2: evictions-per-set histogram (J=9, N={n_objects}, B={B})")
    total = sum(hist.values())
    for k in sorted(set(hist) | set(range(3))):
        c = hist.get(k, 0)
        if c or k <= 10:
            bar = "#" * int(60 * c / max(total, 1))
            print(f"  {k:3d}: {c:9d}  {bar}")
    print(f"# fraction of sets with >1 eviction: {frac_multi:.3f} (paper: 0.16)")
    print(f"# engine: {rep.throughput_rps:,.0f} req/s over {n_requests} requests")
    print(f"# Table V: set exec time MCD-OS {os_mean:.1f}+-{os_std:.1f} us vs "
          f"MCD {mc_mean:.1f}+-{mc_std:.1f} us -> ratio "
          f"{os_mean / max(mc_mean, 1e-9):.2f} (paper 1.15)")
    csv_row("fig2_ripple", tm.seconds * 1e6 / n_requests,
            f"frac_multi={frac_multi:.3f}")
    csv_row("table5_set_overhead", os_mean,
            f"ratio={os_mean / max(mc_mean, 1e-9):.3f};paper=1.15")
    return payload


if __name__ == "__main__":
    main()
