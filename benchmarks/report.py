"""EXPERIMENTS.md generator: render ``benchmarks/artifacts/*.json``.

    PYTHONPATH=src python -m benchmarks.report            # write EXPERIMENTS.md
    PYTHONPATH=src python -m benchmarks.report --stdout   # print instead

Each benchmark records a machine-readable artifact (most embed the exact
scenario spec that produced them — ``repro.scenario.Scenario.from_dict``
reruns it); this module turns the artifact directory into the
human-readable experiment report the repo promises. Unknown artifacts get
a generic summary, so new benchmarks show up without touching this file.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path
from typing import Callable, Dict, List

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"
OUTPUT = ARTIFACTS.parent.parent / "EXPERIMENTS.md"


def _load(name: str) -> dict:
    with open(ARTIFACTS / f"{name}.json") as f:
        return json.load(f)


def _prose(text: str) -> str:
    """A short interpretation paragraph (italicized) closing a section."""
    return f"*{text}*"


def _ranks_table(rows: dict, key: str, ranks=(1, 10, 100, 1000)) -> List[str]:
    has_std = any(
        f"{key}_std" in cell
        for per_proxy in rows.values()
        for cell in per_proxy.values()
    )
    out = [
        "| b | proxy | " + " | ".join(f"h@{r}" for r in ranks) + " |",
        "|---|---|" + "---|" * len(ranks),
    ]
    for b, per_proxy in rows.items():
        for i, cell in per_proxy.items():
            pred, paper = cell[key], cell["paper"]
            std = cell.get(f"{key}_std")
            if std is not None:
                vals = " | ".join(
                    f"{p:.4f}±{s:.4f} ({r:.4f})"
                    for p, s, r in zip(pred, std, paper)
                )
            else:
                vals = " | ".join(
                    f"{p:.4f} ({r:.4f})" for p, r in zip(pred, paper)
                )
            out.append(f"| {b} | {i} | {vals} |")
    out.append("")
    out.append(
        "(parenthesized: paper value"
        + ("; ± is the cross-replica std)" if has_std else ")")
    )
    return out


def _scenario_note(d: dict) -> List[str]:
    scs = d.get("scenarios")
    if scs:
        first = next(iter(scs.values()))
        est = first.get("estimator", {}).get("kind", "?")
        return [
            f"Preset `{d.get('preset', first.get('name', '?'))}` — "
            f"{len(scs)} configurations (estimator `{est}`, "
            f"{first.get('n_requests', 0):,} requests each); every "
            "configuration's exact scenario is embedded in the "
            "artifact's `scenarios` map."
        ]
    sc = d.get("scenario")
    if not sc:
        return []
    est = sc.get("estimator", {}).get("kind", "?")
    return [
        f"Preset `{d.get('preset', sc.get('name', '?'))}` "
        f"(estimator `{est}`, seed {sc.get('seed')}, "
        f"{sc.get('n_requests', 0):,} requests)."
    ]


def render_table1_sim(d: dict) -> List[str]:
    out = _scenario_note(d)
    reps = d.get("replications", 1)
    out += [
        f"Mean relative error vs paper Table I: "
        f"**{d['mean_rel_err_vs_paper']:.4f}** over "
        f"{d['n_requests_per_combo']:,} requests/combo"
        + (
            f" × {reps} independent replicas (cells are cross-replica "
            "means ± std)"
            if reps > 1
            else ""
        )
        + f" ({d.get('engine', 'fastsim')} engine, "
        f"{d.get('engine_requests_per_sec', 0):,.0f} req/s).",
        "",
    ]
    rss = d.get("peak_rss")
    if rss:
        out += [
            f"Peak RSS (one combo): streaming estimator "
            f"**{rss['streaming']['peak_rss_delta_mb']:.1f} MB** vs one-shot "
            f"dense **{rss['dense']['peak_rss_delta_mb']:.1f} MB** — "
            f"**{rss['dense_over_streaming']:.1f}x** lower "
            f"(chunk-fed `{rss['streaming']['backend']}` drive loop + sparse "
            "touched-set occupancy; bit-identical results).",
            "",
        ]
    out += _ranks_table(d["rows"], "sim")
    out += [
        "",
        _prose(
            "Sharing lifts every proxy's head-of-catalogue hit "
            "probability relative to a dedicated cache of the same b "
            "(compare Table III): popular objects appear in several "
            "LRU-lists at once and each list is charged only its share "
            "of the length. Agreement with the paper's Table I is at "
            "the percent level; residual deviation is trajectory noise."
        ),
    ]
    return out


def render_table2_ws(d: dict) -> List[str]:
    out = _scenario_note(d)
    out += [
        f"Mean relative error vs paper Table II: "
        f"**{d['mean_rel_err_vs_paper']:.4f}** (deterministic fixed-point "
        "solve; also the N=1000 calibration evidence).",
        "",
    ]
    out += _ranks_table(d["rows"], "ws")
    out += [
        "",
        _prose(
            "The eq. (8) fixed point reproduces the simulated hit "
            "probabilities of Table I without sampling a single "
            "request — milliseconds instead of minutes — which is what "
            "makes it usable inside the admission controller's online "
            "refresh loop."
        ),
    ]
    return out


def render_table3_noshare(d: dict) -> List[str]:
    out = _scenario_note(d)
    out += [
        f"Mean relative error vs paper Table III: "
        f"**{d['mean_rel_err_vs_paper']:.4f}**. "
        f"Prop. 3.1 dominance (shared >= not-shared, per proxy and "
        f"object): **{d['prop31_dominance_ok']}** "
        f"(worst margin {d['prop31_worst_margin']:+.4f}; mean occupancy "
        f"gain from sharing {d['mean_gain_sharing']:+.4f}).",
        "",
        _prose(
            "The not-shared baseline charges every list the full object "
            "length, so each proxy's effective capacity shrinks; "
            "Prop. 3.1's claim — sharing can only help, for every proxy "
            "and every object — holds pointwise in the simulation."
        ),
    ]
    return out


def render_j2_bounds(d: dict) -> List[str]:
    mb = d["mean_bias"]
    reps = d.get("replications", 1)
    ranks = (1, 10, 100, 1000)
    table = [
        "| proxy | model | " + " | ".join(f"h@{r}" for r in ranks) + " |",
        "|---|---|" + "---|" * len(ranks),
    ]
    for i, row in d["rows"].items():
        std = row.get("sim_std")
        sim = (
            " | ".join(
                f"{p:.4f}±{s:.4f}" for p, s in zip(row["sim"], std)
            )
            if std is not None
            else " | ".join(f"{p:.4f}" for p in row["sim"])
        )
        table.append(f"| {i} | sim | {sim} |")
        for kind in ("L1", "Lstar", "L2"):
            vals = " | ".join(f"{p:.4f}" for p in row[kind])
            table.append(f"| {i} | {kind} | {vals} |")
    return _scenario_note(d) + [
        f"L1 underestimates: **{d['L1_underestimates']}** "
        f"(mean head-rank bias {mb['L1']:+.3f}); "
        f"L2 upper bound: **{d['L2_over_or_upper']}** "
        f"(mean bias {mb['L2']:+.3f})."
        + (
            f" Simulated rows are means over {reps} independent "
            "replicas (± is the cross-replica std)."
            if reps > 1
            else ""
        ),
        "",
        *table,
        "",
        "### Reproduction discrepancies",
        "",
        "The paper claims L1 is ~30% under at J=2; in this implementation "
        "L1 is near-unbiased at J=2 across workloads while the "
        "L2-overestimate claim reproduces — the L1/L2 bracket therefore "
        "still holds, just tighter than reported.",
        "",
        _prose(
            "J=2 is the hardest case for the independence assumption "
            "behind eq. (5): with a single sharing partner the "
            "occupancy correlation is strongest. The L1/L2 pair still "
            "brackets the simulated truth, so either bound is a safe "
            "admission-control input."
        ),
    ]


def render_fig2_ripple(d: dict) -> List[str]:
    hist = {int(k): v for k, v in d["evictions_per_set_histogram"].items()}
    total = sum(hist.values())
    out = _scenario_note(d) + [
        f"Fraction of sets with >1 eviction: "
        f"**{d['frac_multi_eviction']:.3f}** (paper: "
        f"{d['paper_frac_multi_eviction']}); max ripple depth "
        f"{d['max_ripple']} (J=9, N={d['n_objects']:,}, B={d['B']:,}).",
        "",
        "| evictions/set | count | share |",
        "|---|---|---|",
    ]
    for k in sorted(hist):
        out.append(f"| {k} | {hist[k]:,} | {hist[k] / max(total, 1):.1%} |")
    s = d.get("set_us", {})
    if s:
        os_, mc = s["mcd_os"], s["mcd"]
        out += [
            "",
            f"Table V set execution time: MCD-OS {os_['mean']:.1f}±"
            f"{os_['std']:.1f} us vs MCD {mc['mean']:.1f}±{mc['std']:.1f} "
            f"us — overhead ratio **{s['overhead_ratio']:.2f}** "
            f"(paper {s['paper']['overhead_ratio']:.2f}).",
        ]
    out += [
        "",
        _prose(
            "Most set operations evict at most one object, but the "
            "ripple tail (a set in one list forcing evictions in "
            "others through the shared physical budget) is real and "
            "motivates Section IV-D's slack mechanism. The Python "
            "prototype's set-overhead ratio is larger than the paper's "
            "C memcached measurement, as expected for interpreted "
            "bookkeeping."
        ),
    ]
    return out


def render_rre(d: dict) -> List[str]:
    out = _scenario_note(d) + [
        "| config | base ripple | RRE on-path | batch evictions | "
        "giveback | reduction |",
        "|---|---|---|---|---|---|",
    ]
    for key, r in d["results"].items():
        out.append(
            f"| {key} | {r['base_ripple']:,} | {r['rre_ripple_onpath']:,} | "
            f"{r['rre_batch_evictions']:,} | {r['memory_giveback']:,} | "
            f"{r['reduction']:.1%} |"
        )
    out += [
        "",
        _prose(
            "Slack thresholds trade memory for set-path latency: "
            "backing b_hat > b with real memory absorbs the ripple "
            "cascade off the request path (the giveback column is the "
            "memory cost), and delayed batch eviction amortizes what "
            "remains."
        ),
    ]
    return out


def render_slru(d: dict) -> List[str]:
    return _scenario_note(d) + [
        f"Max |hit-rate delta| flat-LRU vs S-LRU: "
        f"**{d['max_abs_delta']:.4f}** over {d['n_requests']:,} requests "
        f"at b={tuple(d['b'])} (paper claim: {d['paper_claim']}).",
        "",
        _prose(
            "Segmenting each list into HOT/WARM/COLD barely moves the "
            "hit rates under IRM traffic, matching the paper's Section "
            "VII observation — the sharing economics, not the "
            "within-list replacement policy, dominate."
        ),
    ]


def render_simthroughput(d: dict) -> List[str]:
    out = []
    for wl_key in ("table1", "fig2"):
        wl = d.get(wl_key)
        if not wl:
            continue
        agg = wl["requests_per_sec"]
        out.append(
            f"- `{wl['workload']}`: reference {agg['reference']:,.0f} req/s, "
            f"fastsim-flat {agg['fastsim-flat']:,.0f}, auto "
            f"{agg['fastsim']:,.0f} — speedup "
            f"**{wl['speedup_auto_vs_reference']:.0f}x** "
            f"(C backend available: {wl['c_backend_available']})."
        )
    xe = d.get("xla_ensemble")
    if xe:
        out.append(
            f"- batched XLA ensemble (R={xe['replications']}, "
            f"{xe['n_requests_per_replica']:,} req/replica): "
            f"**{xe['batched_rps']:,.0f}** aggregate req/s in one "
            f"compiled program vs {xe['sequential_rps']:,.0f} for "
            f"{xe['replications']} sequential single-replica XLA runs — "
            f"**{xe['speedup_batched_vs_sequential']:.2f}x**, replica-0 "
            f"bit-identical to the single-run driver: "
            f"{xe['replica0_bitidentical']} (both sides exclude "
            "compilation)."
        )
    out.append("")
    out.append(d.get("estimator_note", ""))
    out += [
        "",
        _prose(
            "The struct-of-arrays C drive loop turns the Monte-Carlo "
            "estimator from the bottleneck into a routine step — full "
            "paper-scale Table I (80M requests) in seconds — which is "
            "why the scenario layer can afford to validate every "
            "admission episode by simulation."
        ),
    ]
    return out


def render_admission(d: dict) -> List[str]:
    ep = d["episode"]
    n_active = len(ep["active_tenants"])
    n_static = int(ep["capacity"] // max(ep["b_star"].values()))
    out = _scenario_note(d)
    out += [
        f"Online episode at B={ep['capacity']:.0f}: "
        f"**{n_active}** tenants active (static partitioning fits "
        f"{n_static}); {ep['n_rejected']} rejections, "
        f"{ep['n_departed']} departures, {ep['n_evicted']} evictions; "
        f"overbooked: {ep['overbooked']}, overbooking gain "
        f"**{ep['overbooking_gain']:.3f}** "
        f"(committed {ep['committed']:.1f} of {ep['capacity']:.0f} "
        f"physical units against {ep['committed_sla']:.0f} of SLA).",
        "",
        "| tenant | b* | b virtual | predicted SLA hit rate | realized |",
        "|---|---|---|---|---|",
    ]
    for idx, name in enumerate(ep["tenant_names"]):
        out.append(
            f"| {name} | {ep['b_star'][name]:.0f} | "
            f"{ep['b_virtual'][name]:.1f} | "
            f"{ep['predicted_sla_hit_rate'][idx]:.4f} | "
            f"{ep['realized_hit_rate'][idx]:.4f} |"
        )
    out += [
        "",
        f"Max |realized - predicted| SLA hit-rate gap: "
        f"**{ep['max_abs_sla_gap']:.4f}** over "
        f"{d.get('n_validation_requests', 0):,} validation requests "
        f"({d.get('validation_backend', '?')} backend).",
        "",
        "| sweep point | sum b* | sum b virtual | overbooking factor |",
        "|---|---|---|---|",
    ]
    for key, f in d["overbooking_sweep"].items():
        out.append(
            f"| {key} | {f['sum_b_star']:.0f} | {f['sum_b_virtual']:.1f} | "
            f"{f['overbooking_factor']:.3f} |"
        )
    out += [
        "",
        _prose(
            "The controller admits more tenants than the physical cache "
            "could hold unshared, and the per-tenant hit rates it "
            "promised (a dedicated b* cache, eq. (10)) are realized by "
            "the shared system at the smaller virtual allocations — the "
            "gap column above is within Monte-Carlo noise. The sweep "
            "shows the gain growing with tenant count and demand "
            "overlap: more sharing partners means each object's length "
            "is split further (eq. (5))."
        ),
    ]
    return out


def render_serving(d: dict) -> List[str]:
    out = _scenario_note(d) + [
        f"Overlap-vs-disjoint prefix hit-ratio gain: "
        f"**{d['hit_ratio_gain_overlap_vs_disjoint']:.2f}x** "
        f"(90%-shared vs fully disjoint prompt pools, Prop. 3.1 in "
        f"serving form), over {d['n_total_block_events']:,} compiled "
        f"block events total; base-cell rerun bit-identical: "
        f"{d['bitidentical_rerun']}.",
        "",
        "| cell | hit ratio | active | overbooking | FLOPs saved | "
        "p99 latency | SLA gap |",
        "|---|---|---|---|---|---|---|",
    ]
    for key, c in d["sweep"].items():
        out.append(
            f"| {key} | {c['hit_ratio']:.4f} | "
            f"{c['tenants_active']}/{c['tenants_declared']} | "
            f"{c['overbooking_gain']:.2f} | "
            f"{c['prefill_flops_saved']:.3g} | "
            f"{c['latency_p99_s']:.2e} s | {c['max_abs_sla_gap']:.4f} |"
        )
    out += [
        "",
        _prose(
            "The paper's economics transplanted to LLM serving at trace "
            "scale: each cell compiles a multi-tenant prompt-stream "
            "model to a (tenant, KV-block) trace and drives it through "
            "the fastsim C engine, with eq. (13) admission gating the "
            "onboarding. The hit ratio climbs with both overlap and "
            "tenant count (every extra sharing partner splits the "
            "shared blocks' charge further), prefill-FLOPs savings are "
            "priced via the qwen3-1.7b paged-KV layout, and the "
            "realized hit rates stay within Monte-Carlo noise of the "
            "admission controller's dedicated-cache promises."
        ),
    ]
    return out


def render_cluster(d: dict) -> List[str]:
    out = _scenario_note(d) + [
        "",
        "| cell | hit rate | degraded | retries | mean downtime | "
        "recovered |",
        "|---|---|---|---|---|---|",
    ]
    for key, c in d["sweep"].items():
        out.append(
            f"| {key} | {c['overall_hit_rate']:.4f} | "
            f"{c['degraded_requests']:,} | {c['retries']:,} | "
            f"{c['mean_downtime_frac']:.3f} | {c['recovered']} |"
        )
    ep = d["episode"]
    ph = ep["phases"]
    rec = ep["recovery"]
    out += [
        "",
        f"Failover episode ({ep['nodes']} nodes, {ep['vnodes']} vnodes, "
        f"retry budget {ep['retry_budget']}): pre-fault hit rate "
        f"**{ph['pre_fault']['hit_rate']:.4f}**, during outage "
        f"**{ph['during']['hit_rate']:.4f}**, post-recovery "
        f"**{ph['post_recovery']['hit_rate']:.4f}**; "
        f"{ep['retries']['total']:,} failover retries, "
        f"{ep['retries']['degraded_requests']:,} degraded requests; "
        f"recovered to within {rec['tol']} of baseline: "
        f"**{rec['recovered']}** "
        f"(+{rec['requests_to_baseline']:,} requests after the warm "
        "restart).",
    ]
    churn = d.get("churn")
    if churn:
        out += [
            "",
            f"Reshard churn at K={churn['K']} "
            f"({len(churn['events'])} membership events: a remove wave "
            "then an add wave):",
            "",
            "| ghost warm-up | hit rate | remap fraction/event | "
            "ghosts injected | recovered | to baseline |",
            "|---|---|---|---|---|---|",
        ]
        for r in churn["runs"]:
            fr = [p["fraction"] for p in r["remap_curve"]]
            rec2 = r["recovery"]
            out.append(
                f"| {'on' if r['warm_remapped'] else 'off'} | "
                f"{r['overall_hit_rate']:.4f} | "
                f"{min(fr):.4f}..{max(fr):.4f} | "
                f"{r['ghosts_injected']:,} | {rec2['recovered']} | "
                f"{rec2['requests_to_baseline']:,} requests |"
            )
        fw = churn.get("fail_wave")
        if fw:
            out += [
                "",
                f"Fail wave at K={fw['K']} ({len(fw['events'])} "
                f"fail/recover events): hit rate "
                f"**{fw['overall_hit_rate']:.4f}**, "
                f"{fw['degraded_requests']:,} degraded requests, "
                f"{fw['retries']:,} failover retries, mean downtime "
                f"{fw['mean_downtime_frac']:.3f}; recovered: "
                f"**{fw['recovery']['recovered']}** — the run sustains "
                f"{fw['requests_per_sec']:,.0f} req/s because the "
                "failover tables are rebuilt by an O(M) segment walk "
                "over the ring (the former per-slot walk was quadratic "
                "in ring positions, prohibitive at K=100).",
            ]
    sp = d.get("speedup")
    if sp:
        out += [
            "",
            f"Parallel executor (K={sp['K']}, {sp['workers']} workers, "
            f"{sp['backend']} backend, {sp['cpu_count']} visible "
            f"core(s)): sequential {sp['sequential_seconds']}s vs "
            f"parallel {sp['parallel_seconds']}s — "
            f"**{sp['speedup']}x** wall clock, bit-identical estimates "
            "and telemetry. Measured against the "
            f"{sp['target_speedup']}x multi-core target: "
            f"{'met' if sp['meets_target'] else 'not met on this host'} "
            "— the ratio is recorded honestly next to the visible core "
            "count (forked workers sharing one core serialize), and "
            "the CI smoke job enforces its floor only on multi-core "
            "hosts.",
        ]
    out += [
        "",
        _prose(
            "Consistent hashing keeps the fault blast radius at one "
            "node's arc of the ring: the f=0 column shows K-way "
            "partitioning alone barely moves the aggregate hit rate, "
            "and during an outage the failover client degrades only "
            "the failed node's key share (bounded by its ring "
            "fraction) before the warm restart pulls the cluster back "
            "to baseline within a few windows. At K=100 each "
            "membership event remaps ~1/K of the key space (the "
            "minimal-disruption property at scale), so even an "
            "eight-event churn storm moves under a tenth of the keys "
            "end to end, and ghost warm-up of the remapped arcs trims "
            "the post-reshard cold-miss dip."
        ),
    ]
    return out


def render_cluster_smoke(d: dict) -> List[str]:
    out = render_generic(d)
    p = d.get("parallel")
    if p:
        out += [
            "",
            f"Parallel executor leg: K={p['K']} over {p['workers']} "
            "workers, bit-identical to the sequential reference "
            f"(estimates and telemetry); wall-clock speedup "
            f"{p['speedup']}x on {p['cpu_count']} visible core(s), "
            f"{p['speedup_floor']}x floor "
            f"{'enforced' if p['floor_enforced'] else 'not enforced on this host'}.",
        ]
    return out


def render_generic(d: dict) -> List[str]:
    scalars = {
        k: v
        for k, v in d.items()
        if isinstance(v, (int, float, str, bool)) and not k.startswith("_")
    }
    out = _scenario_note(d) + ["| key | value |", "|---|---|"]
    for k, v in sorted(scalars.items()):
        out.append(f"| {k} | {v} |")
    return out


RENDERERS: Dict[str, Callable[[dict], List[str]]] = {
    "table1_sim": render_table1_sim,
    "table2_ws": render_table2_ws,
    "table3_noshare": render_table3_noshare,
    "j2_bounds": render_j2_bounds,
    "fig2_ripple": render_fig2_ripple,
    "rre": render_rre,
    "slru": render_slru,
    "simthroughput": render_simthroughput,
    "admission": render_admission,
    "cluster": render_cluster,
    "cluster_smoke": render_cluster_smoke,
    "serving": render_serving,
}

TITLES = {
    "table1_sim": "Table I — simulated hit probabilities (shared cache)",
    "table2_ws": "Table II — working-set approximation",
    "table3_noshare": "Table III — not-shared baseline + Prop. 3.1",
    "j2_bounds": "J=2 attribution bounds (L1/Lstar/L2)",
    "fig2_ripple": "Fig. 2 + Table V — ripple evictions & set overhead",
    "rre": "Section IV-D — Reducing Ripple Evictions",
    "slru": "Section VII — Segmented LRU under sharing",
    "simthroughput": "Monte-Carlo engine throughput",
    "admission": "Section IV-C — overbooking & admission control",
    "cluster": "Section VI — fault-tolerant MCD-OS cluster (churn & failover)",
    "cluster_smoke": "Cluster smoke (CI gate)",
    "serving": "Serving — multi-tenant KV prefix-cache sweep",
    "serving_smoke": "Serving smoke (CI gate)",
}


def build() -> str:
    names = sorted(p.stem for p in ARTIFACTS.glob("*.json"))
    ordered = [n for n in RENDERERS if n in names] + [
        n for n in names if n not in RENDERERS
    ]
    lines = [
        "# EXPERIMENTS",
        "",
        "Auto-generated by `python -m benchmarks.report` from the "
        "committed `benchmarks/artifacts/*.json` — do not edit by "
        "hand; rerun `python -m benchmarks.run` (optionally "
        "`REPRO_FULL=1`) and regenerate. CI's `docs` job fails if this "
        "file drifts from the artifacts. Artifacts embedding a "
        "`scenario` block (or a `scenarios` map for swept benchmarks) "
        "can be reproduced exactly via "
        "`repro.scenario.Scenario.from_dict(...).run()` on each "
        "embedded spec.",
        "",
    ]
    for name in ordered:
        try:
            d = _load(name)
        except Exception as e:  # unreadable artifact: note and move on
            lines += [f"## {name}", "", f"(unreadable artifact: {e})", ""]
            continue
        lines.append(f"## {TITLES.get(name, name)}")
        lines.append("")
        renderer = RENDERERS.get(name, render_generic)
        try:
            lines += renderer(d)
        except Exception as e:
            lines += [f"(renderer failed: {e}; falling back)", ""]
            lines += render_generic(d)
        lines.append("")
    return "\n".join(lines).rstrip() + "\n"


def main() -> None:
    text = build()
    if "--stdout" in sys.argv[1:]:
        print(text)
        return
    OUTPUT.write_text(text)
    print(f"wrote {OUTPUT} ({len(text.splitlines())} lines, "
          f"{len(list(ARTIFACTS.glob('*.json')))} artifacts)")


if __name__ == "__main__":
    main()
