"""Fault-tolerant cluster: K x failure-rate sweep + the failover episode.

Two parts, both on the Section VI-C Zipf workload (1e6-object catalogue
at full scale, J=9 heterogeneous proxies):

1. **K x failure-rate sweep** — shard the workload across K MCD-OS
   nodes behind the consistent-hash ring and inject ``f`` seeded-random
   fail/recover pairs; record the aggregate hit rate, degraded-request
   count, retry volume, and mean node downtime per cell. The f=0 column
   is the fault-free sharding baseline (how much hit rate K-way
   partitioning itself costs against one big node).
2. **Failover episode** — the ``cluster_failover`` preset (kill node 1
   at 40% of the trace, warm-recover at 60%): per-phase hit rates,
   remap fractions, and the recovery time-to-baseline.

Artifact: ``benchmarks/artifacts/cluster.json`` (rendered into
EXPERIMENTS.md §Cluster by ``python -m benchmarks.report``).
"""

from __future__ import annotations

import dataclasses

from repro.core.cluster import FaultSpec
from repro.scenario import get_preset

from .common import FULL, Timer, csv_row, fig2_scale_factors, quick_mode, save_artifact


def _sweep_grids():
    if quick_mode():
        return (2, 4), (0, 2)
    return (2, 4, 8), (0, 1, 3)


def main() -> dict:
    req, cat = fig2_scale_factors()
    K_grid, failure_grid = _sweep_grids()
    base = get_preset("cluster_failover").scaled(requests=req, catalogue=cat)

    cells: dict = {}
    total_requests = 0
    with Timer() as tm:
        for K in K_grid:
            for f in failure_grid:
                sc = dataclasses.replace(
                    base,
                    name=f"cluster_sweep_K{K}_f{f}",
                    system=dataclasses.replace(
                        base.system,
                        nodes=K,
                        faults=FaultSpec(random_failures=f),
                    ),
                )
                rep = sc.run()
                cl = rep.extras["cluster"]
                phase = cl["phases"].get("steady") or cl["phases"].get(
                    "post_recovery"
                )
                cells[f"K={K},failures={f}"] = {
                    "K": K,
                    "random_failures": f,
                    "overall_hit_rate": float(rep.overall_hit_rate),
                    "realized_overall": (
                        float(phase["hit_rate"]) if phase else None
                    ),
                    "degraded_requests": cl["retries"]["degraded_requests"],
                    "retries": cl["retries"]["total"],
                    "mean_downtime_frac": (
                        sum(p["downtime_frac"] for p in cl["per_node"])
                        / max(len(cl["per_node"]), 1)
                    ),
                    "recovered": cl["recovery"]["recovered"],
                    "requests_per_sec": float(rep.throughput_rps),
                }
                total_requests += rep.n_requests

        # the headline failover episode (scheduled kill + warm recover)
        episode_rep = base.run()
        episode = episode_rep.extras["cluster"]
        total_requests += episode_rep.n_requests

    payload = {
        "preset": "cluster_failover",
        "scenario": base.to_dict(),
        "sweep": cells,
        "episode": episode,
        "full_scale": FULL,
    }
    save_artifact("cluster", payload)

    print("# K x failure-rate sweep (aggregate demand-weighted hit rate)")
    for key, c in cells.items():
        print(
            f"  {key}: hit={c['overall_hit_rate']:.4f} "
            f"degraded={c['degraded_requests']} retries={c['retries']} "
            f"downtime={c['mean_downtime_frac']:.3f}"
        )
    ph = episode["phases"]
    print(
        f"# failover episode: pre={ph['pre_fault']['hit_rate']:.4f} "
        f"during={ph['during']['hit_rate']:.4f} "
        f"post={ph['post_recovery']['hit_rate']:.4f} "
        f"recovered={episode['recovery']['recovered']} "
        f"(+{episode['recovery']['requests_to_baseline']} requests)"
    )
    csv_row(
        "cluster",
        tm.seconds * 1e6 / max(total_requests, 1),
        f"cells={len(cells)};episode_recovered="
        f"{episode['recovery']['recovered']}",
    )
    return payload


if __name__ == "__main__":
    main()
