"""Fault-tolerant cluster: sweeps, failover episode, K=100 churn, and
the parallel-executor speedup measurement.

Four parts, all on the Section VI-C Zipf workload (1e6-object
catalogue at full scale, J=9 heterogeneous proxies):

1. **K x failure-rate sweep** — shard the workload across K MCD-OS
   nodes behind the consistent-hash ring and inject ``f`` seeded-random
   fail/recover pairs; record the aggregate hit rate, degraded-request
   count, retry volume, and mean node downtime per cell. The f=0 column
   is the fault-free sharding baseline (how much hit rate K-way
   partitioning itself costs against one big node).
2. **Failover episode** — the ``cluster_failover`` preset (kill node 1
   at 40% of the trace, warm-recover at 60%): per-phase hit rates,
   remap fractions, and the recovery time-to-baseline.
3. **K=100 reshard-churn sweep** — a remove wave then an add wave
   across a 100-node ring, with ghost warm-up of remapped keys on and
   off: per-event remap fractions, the windowed hit-rate curve through
   the churn, and the time back to baseline. A third leg layers a
   *fail wave* (three staggered fail/recover pairs) on the same
   100-node ring — feasible since the failover-table construction
   became an O(M) segment walk over the ring (it was quadratic in
   ring positions, which made K=100 fail events impractical).
4. **Parallel executor speedup** — the same K=16 run through
   ``executor="sequential"`` and ``executor="parallel"`` (8 workers,
   C backend): asserts bit-identity of estimates and telemetry, then
   records the honest wall-clock ratio next to ``os.cpu_count()``.
   The ratio is a *measurement*, not an assertion — on a single-core
   host the pool cannot beat the sequential pass (the CI smoke job
   gates its speedup floor on the visible core count for the same
   reason).

Artifact: ``benchmarks/artifacts/cluster.json`` (rendered into
EXPERIMENTS.md §Cluster by ``python -m benchmarks.report``).
"""

from __future__ import annotations

import dataclasses
import os
import time

from repro.core.cluster import FaultSpec
from repro.scenario import get_preset

from .common import FULL, Timer, csv_row, fig2_scale_factors, quick_mode, save_artifact

# Reshard-churn sweep: a wave of four removals, then a wave of four
# additions (fresh node ids above the initial range), spread so every
# event lands in its own windowed-hit-rate segment.
CHURN_K = 100
CHURN_EVENTS = (
    (0.30, "remove", 3),
    (0.34, "remove", 17),
    (0.38, "remove", 41),
    (0.42, "remove", 76),
    (0.55, "add", 100),
    (0.60, "add", 101),
    (0.65, "add", 102),
    (0.70, "add", 103),
)

# Fail wave on the same 100-node ring: three staggered outages, each
# recovered before the next leg of the churn comparison window ends.
# Exercises the O(M) failover-table path at K=100 (the old quadratic
# construction made fail events at this scale impractical).
FAIL_WAVE_EVENTS = (
    (0.30, "fail", 7),
    (0.38, "fail", 23),
    (0.46, "fail", 58),
    (0.58, "recover", 7),
    (0.64, "recover", 23),
    (0.70, "recover", 58),
)

SPEEDUP_K = 16
SPEEDUP_WORKERS = 8
SPEEDUP_TARGET = 3.0  # the acceptance floor on a multi-core host


def _sweep_grids():
    if quick_mode():
        return (2, 4), (0, 2)
    return (2, 4, 8), (0, 1, 3)


def _with_cluster(base, *, nodes, faults, executor="sequential", workers=None):
    return dataclasses.replace(
        base,
        name=f"cluster_K{nodes}_{executor}",
        system=dataclasses.replace(
            base.system,
            nodes=nodes,
            faults=faults,
            executor=executor,
            workers=workers,
        ),
    )


def _churn_run(base, warm: bool) -> dict:
    spec = FaultSpec(events=CHURN_EVENTS, warm_remapped=warm)
    sc = _with_cluster(base, nodes=CHURN_K, faults=spec)
    rep = sc.run()
    cl = rep.extras["cluster"]
    return {
        "warm_remapped": warm,
        "overall_hit_rate": float(rep.overall_hit_rate),
        # remap-fraction curve: one point per membership event
        "remap_curve": [
            {
                "idx": r["idx"],
                "action": r["action"],
                "node": r["node"],
                "fraction": r["fraction"],
            }
            for r in cl["remap"]
        ],
        # windowed hit rate through the churn (the recovery shape)
        "windows": cl["windows"],
        "recovery": cl["recovery"],
        "ghosts_injected": cl["warm_remapped"]["injected"],
        "requests": rep.n_requests,
    }


def _fail_wave_run(base) -> dict:
    """Three fail/recover pairs on the K=100 ring (failover tables at
    scale). Times the run so the O(M) table construction shows up as
    ordinary throughput rather than a K^2 cliff."""
    spec = FaultSpec(events=FAIL_WAVE_EVENTS)
    sc = _with_cluster(base, nodes=CHURN_K, faults=spec)
    sc = dataclasses.replace(sc, name=f"cluster_K{CHURN_K}_failwave")
    t0 = time.perf_counter()
    rep = sc.run()
    seconds = time.perf_counter() - t0
    cl = rep.extras["cluster"]
    return {
        "K": CHURN_K,
        "events": [list(e) for e in FAIL_WAVE_EVENTS],
        "overall_hit_rate": float(rep.overall_hit_rate),
        "degraded_requests": cl["retries"]["degraded_requests"],
        "retries": cl["retries"]["total"],
        "mean_downtime_frac": (
            sum(p["downtime_frac"] for p in cl["per_node"])
            / max(len(cl["per_node"]), 1)
        ),
        "recovery": cl["recovery"],
        "seconds": round(seconds, 4),
        "requests_per_sec": float(rep.throughput_rps),
        "requests": rep.n_requests,
    }


def _speedup_run(base) -> dict:
    """Sequential vs parallel wall clock on the identical K=16 run.

    Bit-identity is asserted; the speedup is recorded honestly next to
    the visible core count (a 1-core container measures ~<=1x no
    matter how correct the pool is)."""
    seq_sc = _with_cluster(base, nodes=SPEEDUP_K, faults=FaultSpec())
    par_sc = _with_cluster(
        base,
        nodes=SPEEDUP_K,
        faults=FaultSpec(),
        executor="parallel",
        workers=SPEEDUP_WORKERS,
    )
    t0 = time.perf_counter()
    seq = seq_sc.run()
    t_seq = time.perf_counter() - t0
    t0 = time.perf_counter()
    par = par_sc.run()
    t_par = time.perf_counter() - t0

    if not par.same_estimates(seq):
        raise RuntimeError("parallel executor diverged from sequential")
    if par.extras["cluster"] != seq.extras["cluster"]:
        raise RuntimeError("parallel cluster telemetry diverged")

    speedup = t_seq / max(t_par, 1e-9)
    return {
        "K": SPEEDUP_K,
        "workers": SPEEDUP_WORKERS,
        "backend": seq.backend,
        "cpu_count": os.cpu_count(),
        "sequential_seconds": round(t_seq, 4),
        "parallel_seconds": round(t_par, 4),
        "speedup": round(speedup, 3),
        "target_speedup": SPEEDUP_TARGET,
        "meets_target": speedup >= SPEEDUP_TARGET,
        "bit_identical": True,
        "requests": seq.n_requests + par.n_requests,
    }


def main() -> dict:
    req, cat = fig2_scale_factors()
    K_grid, failure_grid = _sweep_grids()
    base = get_preset("cluster_failover").scaled(requests=req, catalogue=cat)

    cells: dict = {}
    total_requests = 0
    with Timer() as tm:
        for K in K_grid:
            for f in failure_grid:
                sc = dataclasses.replace(
                    base,
                    name=f"cluster_sweep_K{K}_f{f}",
                    system=dataclasses.replace(
                        base.system,
                        nodes=K,
                        faults=FaultSpec(random_failures=f),
                    ),
                )
                rep = sc.run()
                cl = rep.extras["cluster"]
                phase = cl["phases"].get("steady") or cl["phases"].get(
                    "post_recovery"
                )
                cells[f"K={K},failures={f}"] = {
                    "K": K,
                    "random_failures": f,
                    "overall_hit_rate": float(rep.overall_hit_rate),
                    "realized_overall": (
                        float(phase["hit_rate"]) if phase else None
                    ),
                    "degraded_requests": cl["retries"]["degraded_requests"],
                    "retries": cl["retries"]["total"],
                    "mean_downtime_frac": (
                        sum(p["downtime_frac"] for p in cl["per_node"])
                        / max(len(cl["per_node"]), 1)
                    ),
                    "recovered": cl["recovery"]["recovered"],
                    "requests_per_sec": float(rep.throughput_rps),
                }
                total_requests += rep.n_requests

        # the headline failover episode (scheduled kill + warm recover)
        episode_rep = base.run()
        episode = episode_rep.extras["cluster"]
        total_requests += episode_rep.n_requests

        # K=100 reshard churn, ghost warm-up off and on
        churn = {
            "K": CHURN_K,
            "events": [list(e) for e in CHURN_EVENTS],
            "runs": [_churn_run(base, warm) for warm in (False, True)],
        }
        total_requests += sum(r["requests"] for r in churn["runs"])

        # K=100 fail wave (failover tables at scale, now O(M))
        churn["fail_wave"] = _fail_wave_run(base)
        total_requests += churn["fail_wave"]["requests"]

        # sequential vs parallel executor on the identical K=16 run
        speedup = _speedup_run(base)
        total_requests += speedup["requests"]

    payload = {
        "preset": "cluster_failover",
        "scenario": base.to_dict(),
        "sweep": cells,
        "episode": episode,
        "churn": churn,
        "speedup": speedup,
        "full_scale": FULL,
    }
    save_artifact("cluster", payload)

    print("# K x failure-rate sweep (aggregate demand-weighted hit rate)")
    for key, c in cells.items():
        print(
            f"  {key}: hit={c['overall_hit_rate']:.4f} "
            f"degraded={c['degraded_requests']} retries={c['retries']} "
            f"downtime={c['mean_downtime_frac']:.3f}"
        )
    ph = episode["phases"]
    print(
        f"# failover episode: pre={ph['pre_fault']['hit_rate']:.4f} "
        f"during={ph['during']['hit_rate']:.4f} "
        f"post={ph['post_recovery']['hit_rate']:.4f} "
        f"recovered={episode['recovery']['recovered']} "
        f"(+{episode['recovery']['requests_to_baseline']} requests)"
    )
    for r in churn["runs"]:
        fracs = [p["fraction"] for p in r["remap_curve"]]
        print(
            f"# K={CHURN_K} churn warm={r['warm_remapped']}: "
            f"hit={r['overall_hit_rate']:.4f} "
            f"remap_frac={min(fracs):.4f}..{max(fracs):.4f} "
            f"ghosts={r['ghosts_injected']} "
            f"recovered={r['recovery']['recovered']}"
        )
    fw = churn["fail_wave"]
    print(
        f"# K={CHURN_K} fail wave ({len(FAIL_WAVE_EVENTS)} events): "
        f"hit={fw['overall_hit_rate']:.4f} "
        f"degraded={fw['degraded_requests']} retries={fw['retries']} "
        f"recovered={fw['recovery']['recovered']} "
        f"({fw['seconds']}s, {fw['requests_per_sec']:.0f} req/s)"
    )
    print(
        f"# parallel executor: K={speedup['K']} "
        f"workers={speedup['workers']} cores={speedup['cpu_count']} "
        f"seq={speedup['sequential_seconds']}s "
        f"par={speedup['parallel_seconds']}s "
        f"speedup={speedup['speedup']}x "
        f"(target {speedup['target_speedup']}x, bit-identical)"
    )
    csv_row(
        "cluster",
        tm.seconds * 1e6 / max(total_requests, 1),
        f"cells={len(cells)};episode_recovered="
        f"{episode['recovery']['recovered']};"
        f"speedup={speedup['speedup']}x@{speedup['cpu_count']}cores",
    )
    return payload


if __name__ == "__main__":
    main()
