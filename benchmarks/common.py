"""Shared configuration + helpers for the paper-reproduction benchmarks.

The paper experiments are defined once, at paper scale, as named
presets in :mod:`repro.scenario.presets`; every benchmark routes through
``get_preset(...).scaled(...)``. This module only maps the harness's
three fidelity modes onto scale factors:

* default: reduced sizes so ``python -m benchmarks.run`` finishes in a few
  minutes on one CPU core;
* ``REPRO_FULL=1``: the paper's full experiment scale (factor 1.0);
* ``--quick`` / ``REPRO_QUICK=1``: smoke scale, every benchmark in
  seconds, used by CI.

All benchmarks write machine-readable artifacts to
``benchmarks/artifacts/*.json`` (consumed by ``python -m
benchmarks.report``, which renders EXPERIMENTS.md) and print
``name,us_per_call,derived`` CSV rows per the harness contract. The
artifacts are committed alongside EXPERIMENTS.md, and CI's ``docs`` job
(``tools/check_docs.py``) fails when the two disagree — regenerate both
together.
"""

from __future__ import annotations

import json
import os
import threading
import time
from pathlib import Path
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"
ARTIFACTS.mkdir(exist_ok=True)

FULL = os.environ.get("REPRO_FULL", "0") not in ("0", "", "false")

# Smoke scale: every benchmark runs in seconds so CI can exercise the
# whole harness end to end. Set by ``python -m benchmarks.run --quick``
# or REPRO_QUICK=1; read it via quick_mode() (run.py flips it after
# import).
QUICK = os.environ.get("REPRO_QUICK", "0") not in ("0", "", "false")


def quick_mode() -> bool:
    return QUICK and not FULL

# ---------------------------------------------------------------------------
# The paper's Section V setup (Tables I-III): J=3 LRU-lists over a B=1000
# physical cache, unit-length objects, Zipf alphas (0.75, 0.5, 1.0),
# allocations b_i in {8, 64}. Catalogue size N is not stated in the paper;
# N=1000 was calibrated against Table II (see DESIGN.md §7).
# ---------------------------------------------------------------------------
N_OBJECTS = 1000
B_PHYSICAL = 1000
ALPHAS = (0.75, 0.5, 1.0)
B_GRID: List[Tuple[int, int, int]] = [
    (8, 8, 8), (8, 8, 64), (8, 64, 8), (8, 64, 64),
    (64, 8, 8), (64, 8, 64), (64, 64, 8), (64, 64, 64),
]
RANKS = (1, 10, 100, 1000)

# Paper Table I (empirical, shared): {b_combo: {proxy: [h at RANKS]}}
TABLE1 = {
    (8, 8, 8):    {0: [.368, .0758, .0142, .00226], 1: [.126, .0412, .0130, .00423], 2: [.708, .1142, .0121, .00116]},
    (8, 8, 64):   {0: [.407, .0877, .0158, .00273], 1: [.136, .0448, .0138, .00438], 2: [1.000, .7560, .1292, .01411]},
    (8, 64, 8):   {0: [.389, .0823, .0149, .00271], 1: [.676, .2991, .1069, .03422], 2: [.745, .1281, .0130, .00146]},
    (8, 64, 64):  {0: [.422, .0924, .0167, .0028],  1: [.699, .3205, .1131, .03574], 2: [1.000, .7882, .1419, .01628]},
    (64, 8, 8):   {0: [.983, .5138, .1170, .02303], 1: [.136, .0438, .0136, .00425], 2: [.771, .1383, .0146, .00168]},
    (64, 8, 64):  {0: [.989, .5568, .1325, .02660], 1: [.143, .0476, .0146, .00458], 2: [1.000, .7968, .1419, .01435]},
    (64, 64, 8):  {0: [.986, .5387, .1262, .02366], 1: [.699, .3159, .1129, .03639], 2: [.793, .1502, .0147, .00153]},
    (64, 64, 64): {0: [.992, .5763, .1445, .02724], 1: [.726, .3318, .1205, .03916], 2: [1.000, .8196, .1597, .01416]},
}

# Paper Table II (working-set approximation with L1/eq.(5), same system).
TABLE2 = {
    (8, 8, 8):    {0: [.365, .0776, .0143, .00255], 1: [.126, .0416, .0133, .00424], 2: [.694, .1116, .0118, .00118]},
    (8, 8, 64):   {0: [.401, .0872, .0161, .00288], 1: [.134, .0446, .0143, .00455], 2: [1.000, .7556, .1314, .01399]},
    (8, 64, 8):   {0: [.386, .0832, .0153, .00274], 1: [.678, .3011, .1071, .03519], 2: [.734, .1242, .0132, .00133]},
    (8, 64, 64):  {0: [.421, .0926, .0171, .00307], 1: [.704, .3197, .1147, .03779], 2: [1.000, .7861, .1429, .01530]},
    (64, 8, 8):   {0: [.984, .5213, .1228, .02302], 1: [.133, .0442, .0142, .00451], 2: [.756, .1314, .0140, .00141]},
    (64, 8, 64):  {0: [.990, .5622, .1366, .02579], 1: [.142, .0472, .0152, .00482], 2: [1.000, .7995, .1484, .01594]},
    (64, 64, 8):  {0: [.988, .5455, .1308, .02463], 1: [.701, .3171, .1136, .03742], 2: [.787, .1434, .0154, .00155]},
    (64, 64, 64): {0: [.993, .5846, .1446, .02740], 1: [.725, .3353, .1212, .04002], 2: [1.000, .8249, .1599, .01727]},
}

# Paper Table III (not-shared baseline at b=(64,64,8)).
TABLE3 = {
    (64, 64, 8): {0: [.9800, .5084, .11760, .02259], 1: [.6683, .2944, .10437, .03503], 2: [.7005, .1123, .01176, .00113]},
}

def section5_scale() -> Tuple[float, float]:
    """(requests_factor, catalogue_factor) for the Section V presets
    (Tables I-III, J=2, S-LRU). The catalogue never shrinks: the Table
    I/II numbers are calibrated at N=1000."""
    if FULL:
        return 1.0, 1.0
    return (0.01, 1.0) if quick_mode() else (0.15, 1.0)


def fig2_scale_factors() -> Tuple[float, float]:
    """(requests_factor, catalogue_factor) for the Section VI-C presets
    (Fig. 2 / RRE): 10x down by default — same shape, same b/N regime —
    and 100x down for smoke runs."""
    if FULL:
        return 1.0, 1.0
    return (0.01, 0.01) if quick_mode() else (0.1, 0.1)


def save_artifact(name: str, payload: dict) -> Path:
    path = ARTIFACTS / f"{name}.json"
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=_json_default)
    return path


def load_artifact(name: str) -> dict:
    with open(ARTIFACTS / f"{name}.json") as f:
        return json.load(f)


def _json_default(obj):
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    raise TypeError(f"not JSON serializable: {type(obj)}")


def csv_row(name: str, us_per_call: float, derived: str) -> None:
    """The harness contract: ``name,us_per_call,derived``."""
    print(f"{name},{us_per_call:.3f},{derived}")


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self.t0
        return False


class PeakRSS:
    """Peak resident-set-size sampler over a code block.

    A background thread polls ``/proc/self/statm`` every few
    milliseconds; ``delta_mb`` reports the peak RSS *above the entry
    baseline*, so successive blocks in one process measure their own
    allocations rather than the process high-water mark (which only
    ever grows). Sustained allocations — a materialized trace, dense
    accumulators — are what the streaming-vs-dense comparison cares
    about, and those are held for whole run phases, far longer than the
    sampling interval. On platforms without /proc, ``supported`` is
    False and the deltas read 0.
    """

    def __init__(self, interval_s: float = 0.002) -> None:
        self.interval_s = interval_s
        self.supported = True
        try:
            self._page_mb = os.sysconf("SC_PAGE_SIZE") / 1e6
            self._read()
        except (OSError, ValueError, AttributeError):
            self.supported = False
        self.baseline_mb = 0.0
        self.peak_mb = 0.0

    def _read(self) -> float:
        with open("/proc/self/statm") as f:
            return int(f.read().split()[1]) * self._page_mb

    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                self.peak_mb = max(self.peak_mb, self._read())
            except OSError:  # pragma: no cover
                break
            self._stop.wait(self.interval_s)

    def __enter__(self) -> "PeakRSS":
        if self.supported:
            self.baseline_mb = self.peak_mb = self._read()
            self._stop = threading.Event()
            self._thread = threading.Thread(target=self._run, daemon=True)
            self._thread.start()
        return self

    def __exit__(self, *exc):
        if self.supported:
            self.peak_mb = max(self.peak_mb, self._read())
            self._stop.set()
            self._thread.join()
        return False

    @property
    def delta_mb(self) -> float:
        return max(self.peak_mb - self.baseline_mb, 0.0)


def rel_err(pred: float, ref: float, floor: float = 1e-9) -> float:
    return abs(pred - ref) / max(abs(ref), floor)


def mean_rel_err(pred: Iterable[float], ref: Iterable[float]) -> float:
    errs = [rel_err(p, r) for p, r in zip(pred, ref)]
    return float(np.mean(errs)) if errs else float("nan")
