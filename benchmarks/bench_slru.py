"""Section VII: Segmented-LRU variant under object sharing.

The paper reports that cache-hit probabilities change by only ~2-3 %
between flat LRU and S-LRU under object sharing. We run both on the same
trace and report the per-proxy overall hit-rate delta.
"""

from __future__ import annotations

import numpy as np

from repro.core import GetResult, SharedLRUCache, rate_matrix, sample_trace
from repro.core.slru import SegmentedSharedLRUCache

from .common import ALPHAS, B_PHYSICAL, N_OBJECTS, Timer, csv_row, save_artifact, table1_requests


def run(cache_cls, b, trace, **kw):
    cache = cache_cls(list(b), physical_capacity=B_PHYSICAL, **kw)
    hits = np.zeros(len(b))
    reqs = np.zeros(len(b))
    warmup = len(trace.proxies) // 10
    P, O = trace.proxies.tolist(), trace.objects.tolist()
    for idx in range(len(P)):
        i, k = P[idx], O[idx]
        st = cache.get(i, k)
        if st.result is GetResult.MISS:
            cache.set(i, k, 1)
        if idx >= warmup:
            reqs[i] += 1
            hits[i] += st.result is GetResult.HIT_LIST
    cache.check_invariants()
    return hits / np.maximum(reqs, 1)


def main() -> dict:
    b = (64, 64, 64)
    n_requests = max(table1_requests() // 3, 300_000)
    lam = rate_matrix(N_OBJECTS, list(ALPHAS))
    trace = sample_trace(lam, n_requests, seed=13)

    with Timer() as tm:
        h_flat = run(SharedLRUCache, b, trace)
        h_slru = run(SegmentedSharedLRUCache, b, trace)

    delta = h_slru - h_flat
    payload = {
        "b": b,
        "n_requests": n_requests,
        "hit_rate_flat": h_flat.tolist(),
        "hit_rate_slru": h_slru.tolist(),
        "delta": delta.tolist(),
        "max_abs_delta": float(np.max(np.abs(delta))),
        "paper_claim": "~2-3% difference",
    }
    save_artifact("slru", payload)

    print(f"# S-LRU vs flat LRU under object sharing (b={b})")
    for i in range(3):
        print(f"  proxy {i}: flat={h_flat[i]:.4f}  slru={h_slru[i]:.4f} "
              f"delta={delta[i]:+.4f}")
    print(f"# max |delta| = {np.max(np.abs(delta)):.4f} (paper: ~0.02-0.03)")
    csv_row(
        "slru",
        tm.seconds * 1e6 / (2 * n_requests),
        f"max_abs_delta={np.max(np.abs(delta)):.4f}",
    )
    return payload


if __name__ == "__main__":
    main()
