"""Section VII: Segmented-LRU variant under object sharing.

The paper reports that cache-hit probabilities change by only ~2-3 %
between flat LRU and S-LRU under object sharing. We run both on the same
trace and report the per-proxy overall hit-rate delta.

Both systems run on the array engine: the flat cache on the native C/
inlined loop, the S-LRU on the per-operation fast engine
(:class:`repro.core.fastsim.FastSegmentedSharedLRU`, event-equivalent to
the reference ``SegmentedSharedLRUCache``).
"""

from __future__ import annotations

import numpy as np

from repro.core import SimParams, rate_matrix, sample_trace, simulate_trace

from .common import ALPHAS, B_PHYSICAL, N_OBJECTS, Timer, csv_row, save_artifact, table1_requests


def run(variant: str, b, trace):
    res = simulate_trace(
        SimParams(allocations=tuple(b), physical_capacity=B_PHYSICAL,
                  variant=variant),
        trace,
        N_OBJECTS,
        warmup=len(trace) // 10,
    )
    return res.hit_rate_by_proxy


def main() -> dict:
    b = (64, 64, 64)
    n_requests = max(table1_requests() // 3, 300_000)
    lam = rate_matrix(N_OBJECTS, list(ALPHAS))
    trace = sample_trace(lam, n_requests, seed=13)

    with Timer() as tm:
        h_flat = run("lru", b, trace)
        h_slru = run("slru", b, trace)

    delta = h_slru - h_flat
    payload = {
        "b": b,
        "n_requests": n_requests,
        "engine": "fastsim",
        "hit_rate_flat": h_flat.tolist(),
        "hit_rate_slru": h_slru.tolist(),
        "delta": delta.tolist(),
        "max_abs_delta": float(np.max(np.abs(delta))),
        "paper_claim": "~2-3% difference",
    }
    save_artifact("slru", payload)

    print(f"# S-LRU vs flat LRU under object sharing (b={b})")
    for i in range(3):
        print(f"  proxy {i}: flat={h_flat[i]:.4f}  slru={h_slru[i]:.4f} "
              f"delta={delta[i]:+.4f}")
    print(f"# max |delta| = {np.max(np.abs(delta)):.4f} (paper: ~0.02-0.03)")
    csv_row(
        "slru",
        tm.seconds * 1e6 / (2 * n_requests),
        f"max_abs_delta={np.max(np.abs(delta)):.4f}",
    )
    return payload


if __name__ == "__main__":
    main()
