"""Section VII: Segmented-LRU variant under object sharing.

The paper reports that cache-hit probabilities change by only ~2-3 %
between flat LRU and S-LRU under object sharing. The ``slru`` preset and
the ``table1`` preset at the same allocations and seed see the identical
trace; we report the per-proxy overall hit-rate delta.
"""

from __future__ import annotations

import numpy as np

from repro.scenario import get_preset

from .common import Timer, csv_row, save_artifact, section5_scale


def main() -> dict:
    b = (64, 64, 64)
    req_f, cat_f = section5_scale()
    req_f = req_f / 3  # two Python-speed runs; keep the pair affordable
    slru_sc = get_preset("slru", b=b).scaled(req_f, cat_f)
    flat_sc = get_preset("table1", b=b, seed=slru_sc.seed).scaled(req_f, cat_f)
    n_requests = slru_sc.n_requests

    with Timer() as tm:
        flat = flat_sc.run()
        slru = slru_sc.run()
    h_flat = flat.realized_hit_rate
    h_slru = slru.realized_hit_rate

    delta = h_slru - h_flat
    payload = {
        "preset": "slru",
        "scenarios": {"slru": slru_sc.to_dict(), "flat": flat_sc.to_dict()},
        "b": b,
        "n_requests": n_requests,
        "engine": f"{flat.backend}/{slru.backend}",
        "hit_rate_flat": h_flat.tolist(),
        "hit_rate_slru": h_slru.tolist(),
        "delta": delta.tolist(),
        "max_abs_delta": float(np.max(np.abs(delta))),
        "paper_claim": "~2-3% difference",
    }
    save_artifact("slru", payload)

    print(f"# S-LRU vs flat LRU under object sharing (b={b})")
    for i in range(3):
        print(f"  proxy {i}: flat={h_flat[i]:.4f}  slru={h_slru[i]:.4f} "
              f"delta={delta[i]:+.4f}")
    print(f"# max |delta| = {np.max(np.abs(delta)):.4f} (paper: ~0.02-0.03)")
    csv_row(
        "slru",
        tm.seconds * 1e6 / (2 * n_requests),
        f"max_abs_delta={np.max(np.abs(delta)):.4f}",
    )
    return payload


if __name__ == "__main__":
    main()
