"""Paper Table II: working-set approximation (eq. (8) with L1/eq. (5)).

Deterministic — runs the ``table2_ws`` preset (the Table-I system with
the ``working_set`` estimator) for every allocation combination and
compares elementwise against the paper's Table II. This is also the
N-calibration evidence (see DESIGN.md §7): at N=1000 the residuals are
sub-1 %; at N=2000 they exceed 20 %.

The jit-compiled fixed-point solver is cached per hyperparameter set, so
the 8-combo grid costs one XLA compilation and 8 executions.
"""

from __future__ import annotations

import numpy as np

from repro.scenario import get_preset

from .common import (
    B_GRID,
    RANKS,
    TABLE2,
    Timer,
    csv_row,
    mean_rel_err,
    save_artifact,
)


def main() -> dict:
    rows, all_pred, all_ref = {}, [], []
    grid = {b: get_preset("table2_ws", b=b) for b in B_GRID}
    scenarios = {str(b): sc.to_dict() for b, sc in grid.items()}
    with Timer() as tm:
        reports = {b: sc.run() for b, sc in grid.items()}
    total_us = tm.seconds * 1e6
    n_solves = len(B_GRID)
    for b, rep in reports.items():
        assert rep.converged, f"working-set solve did not converge for b={b}"
        assert rep.extras["max_abs_residual"] < 1e-2 * max(b), (
            f"large residual for b={b}: {rep.extras['max_abs_residual']}"
        )
        rows[str(b)] = {}
        for i in range(3):
            pred = rep.hit_prob_at_ranks(i, RANKS)
            ref = TABLE2[b][i]
            rows[str(b)][i] = {"ws": pred, "paper": ref}
            all_pred += pred
            all_ref += ref
    err = mean_rel_err(all_pred, all_ref)
    payload = {
        "preset": "table2_ws",
        "scenarios": scenarios,
        "rows": rows,
        "mean_rel_err_vs_paper": err,
        "solver": "scenario working_set estimator (cached jit solver)",
    }
    save_artifact("table2_ws", payload)

    print("# Table II reproduction (working-set approximation, L1)")
    print("# i  b0  b1  b2   h_1      h_10     h_100    h_1000   (paper in parens)")
    for b in B_GRID:
        for i in range(3):
            pred = rows[str(b)][i]["ws"]
            ref = rows[str(b)][i]["paper"]
            cells = "  ".join(f"{p:.4f}({r:.4f})" for p, r in zip(pred, ref))
            print(f"  {i}  {b[0]:3d} {b[1]:3d} {b[2]:3d}  {cells}")
    csv_row("table2_ws", total_us / n_solves, f"mean_rel_err={err:.4f}")
    return payload


if __name__ == "__main__":
    main()
