"""Paper Table II: working-set approximation (eq. (8) with L1/eq. (5)).

Deterministic — solves the fixed point for every allocation combination
and compares elementwise against the paper's Table II. This is also the
N-calibration evidence (see DESIGN.md §7): at N=1000 the residuals are
sub-1 %; at N=2000 they exceed 20 %.

The 8-combo grid is one ``jax.vmap``-ed jit call
(:func:`repro.core.workingset.solve_workingset_batch`): one compilation
and one XLA execution instead of 8 sequential jit-compiled solves.
"""

from __future__ import annotations

import numpy as np

from repro.core import rate_matrix, solve_workingset_batch

from .common import (
    ALPHAS,
    B_GRID,
    N_OBJECTS,
    RANKS,
    TABLE2,
    Timer,
    csv_row,
    mean_rel_err,
    save_artifact,
)


def main() -> dict:
    lam = rate_matrix(N_OBJECTS, list(ALPHAS))
    lengths = np.ones(N_OBJECTS)
    rows, all_pred, all_ref = {}, [], []
    with Timer() as tm:
        sols = solve_workingset_batch(
            lam, lengths, np.array(B_GRID, float), attribution="L1"
        )
    total_us = tm.seconds * 1e6
    n_solves = len(B_GRID)
    for b, sol in zip(B_GRID, sols):
        assert sol.converged, f"working-set solve did not converge for b={b}"
        assert np.max(np.abs(sol.residual)) < 1e-2 * max(b), (
            f"large residual for b={b}: {sol.residual}"
        )
        rows[str(b)] = {}
        for i in range(3):
            pred = [float(sol.h[i, k - 1]) for k in RANKS]
            ref = TABLE2[b][i]
            rows[str(b)][i] = {"ws": pred, "paper": ref}
            all_pred += pred
            all_ref += ref
    err = mean_rel_err(all_pred, all_ref)
    payload = {
        "rows": rows,
        "mean_rel_err_vs_paper": err,
        "n_objects": N_OBJECTS,
        "solver": "solve_workingset_batch (one vmap-ed jit over the b-grid)",
    }
    save_artifact("table2_ws", payload)

    print("# Table II reproduction (working-set approximation, L1)")
    print("# i  b0  b1  b2   h_1      h_10     h_100    h_1000   (paper in parens)")
    for b in B_GRID:
        for i in range(3):
            pred = rows[str(b)][i]["ws"]
            ref = rows[str(b)][i]["paper"]
            cells = "  ".join(f"{p:.4f}({r:.4f})" for p, r in zip(pred, ref))
            print(f"  {i}  {b[0]:3d} {b[1]:3d} {b[2]:3d}  {cells}")
    csv_row("table2_ws", total_us / n_solves, f"mean_rel_err={err:.4f}")
    return payload


if __name__ == "__main__":
    main()
