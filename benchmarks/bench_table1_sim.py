"""Paper Table I: empirical hit probabilities of the shared-object cache.

Simulates the J=3 system (Zipf 0.75/0.5/1.0, unit objects, B=1000,
b in {8,64}^3) under the IRM and reports the hit probability of objects
at ranks 1/10/100/1000 per proxy, next to the paper's values.

Estimator: exact residence-time occupancy (PASTA) instead of realized-hit
counting — variance-free given the trajectory, which is what lets the
default (1.5M-request) run resolve the 1e-3 tail entries the paper needed
"sufficiently long" simulations for.

Engine: the array-based ``repro.core.fastsim`` drive loop (equivalent to
the reference ``SharedLRUCache`` event for event — see
``tests/test_fastsim.py`` — so the occupancy numbers are bit-identical
to the old per-request reference loop on the same trace, only 2-3 orders
of magnitude faster; ``bench_simthroughput`` tracks the ratio).
"""

from __future__ import annotations

import numpy as np

from repro.core import SimParams, rate_matrix, sample_trace, simulate_trace
from repro.core.fastsim import default_warmup

from .common import (
    ALPHAS,
    B_GRID,
    B_PHYSICAL,
    N_OBJECTS,
    RANKS,
    TABLE1,
    Timer,
    csv_row,
    mean_rel_err,
    save_artifact,
    table1_requests,
)


def simulate_combo(b, n_requests: int, seed: int = 7):
    lam = rate_matrix(N_OBJECTS, list(ALPHAS))
    trace = sample_trace(lam, n_requests, seed=seed)
    res = simulate_trace(
        SimParams(allocations=tuple(b), physical_capacity=B_PHYSICAL),
        trace,
        N_OBJECTS,
        warmup=default_warmup(n_requests, b),
    )
    return res.occupancy, res


def main() -> dict:
    n_requests = table1_requests()
    rows, all_pred, all_ref = {}, [], []
    total_us = 0.0
    engine_us = 0.0
    for b in B_GRID:
        with Timer() as tm:
            h, res = simulate_combo(b, n_requests)
        total_us += tm.seconds * 1e6
        engine_us += res.elapsed_s * 1e6
        rows[str(b)] = {}
        for i in range(3):
            pred = [float(h[i, k - 1]) for k in RANKS]
            ref = TABLE1[b][i]
            rows[str(b)][i] = {"sim": pred, "paper": ref}
            all_pred += pred
            all_ref += ref
    err = mean_rel_err(all_pred, all_ref)
    n_total = len(B_GRID) * n_requests
    payload = {
        "n_requests_per_combo": n_requests,
        "rows": rows,
        "mean_rel_err_vs_paper": err,
        "engine": "fastsim",
        "engine_requests_per_sec": n_total / max(engine_us / 1e6, 1e-9),
    }
    save_artifact("table1_sim", payload)

    print(f"# Table I reproduction (simulated, {n_requests} req/combo)")
    print(f"# i  b0  b1  b2   h_1      h_10     h_100    h_1000   (paper in parens)")
    for b in B_GRID:
        for i in range(3):
            pred = rows[str(b)][i]["sim"]
            ref = rows[str(b)][i]["paper"]
            cells = "  ".join(f"{p:.4f}({r:.4f})" for p, r in zip(pred, ref))
            print(f"  {i}  {b[0]:3d} {b[1]:3d} {b[2]:3d}  {cells}")
    print(
        f"# engine throughput: {payload['engine_requests_per_sec']:,.0f} req/s "
        f"(drive loop only, {len(B_GRID)} combos x {n_requests} requests)"
    )
    csv_row(
        "table1_sim",
        total_us / n_total,
        f"mean_rel_err={err:.4f}",
    )
    return payload


if __name__ == "__main__":
    main()
