"""Paper Table I: empirical hit probabilities of the shared-object cache.

Runs the named ``table1`` preset (J=3, Zipf 0.75/0.5/1.0, unit objects,
B=1000) for every allocation combination ``b in {8,64}^3`` and reports
the hit probability of objects at ranks 1/10/100/1000 per proxy, next to
the paper's values.

Estimator: exact residence-time occupancy (PASTA) instead of realized-hit
counting — variance-free given the trajectory. Engine: whatever backend
``scenario.run()`` picks (the native C loop when a compiler is present;
equivalent event for event to the reference cache, see
``tests/test_fastsim.py``).
"""

from __future__ import annotations

import dataclasses

from repro.scenario import get_preset

from .common import (
    B_GRID,
    RANKS,
    TABLE1,
    PeakRSS,
    Timer,
    csv_row,
    mean_rel_err,
    save_artifact,
    section5_scale,
)


def _peak_rss_compare(scale) -> dict:
    """Streaming vs dense peak RSS on one combo (the ISSUE-3 artifact).

    At REPRO_FULL scale the dense path materializes the 10M-request
    trace (plus its sampling transients) while the streaming estimator
    feeds 250k-request chunks through the chunk-fed engine — the
    recorded ratio is the acceptance criterion (>= 10x at full scale).
    Each mode gets a freshly built scenario (and streaming runs first),
    so the dense run's memoized trace cannot sit in the streaming
    run's baseline.
    """
    modes = {}
    for mode in ("streaming", "dense"):
        sc = get_preset("table1", b=(64, 64, 64)).scaled(*scale)
        sc = dataclasses.replace(
            sc,
            estimator=dataclasses.replace(
                sc.estimator, streaming=(mode == "streaming")
            ),
        )
        with PeakRSS() as pr:
            rep = sc.run()
        modes[mode] = {
            "peak_rss_delta_mb": round(pr.delta_mb, 2),
            "backend": rep.backend,
            "streaming": bool(rep.extras.get("streaming")),
            "supported": pr.supported,
        }
    modes["dense_over_streaming"] = modes["dense"]["peak_rss_delta_mb"] / max(
        modes["streaming"]["peak_rss_delta_mb"], 1e-9
    )
    return modes


def main() -> dict:
    scale = section5_scale()
    # R independent Monte-Carlo replicas per combo: the artifact gains a
    # cross-replica std for every reported hit probability (error bars
    # in EXPERIMENTS.md); replica 0 reproduces the old single-run rows.
    replications = 4
    rows, scenarios, all_pred, all_ref = {}, {}, [], []
    total_us = 0.0
    engine_us = 0.0
    n_requests = n_total = 0
    for b in B_GRID:
        sc = get_preset("table1", b=b).scaled(*scale)
        sc = dataclasses.replace(
            sc,
            estimator=dataclasses.replace(
                sc.estimator, replications=replications
            ),
        )
        scenarios[str(b)] = sc.to_dict()
        n_requests = sc.n_requests
        with Timer() as tm:
            rep = sc.run()
        total_us += tm.seconds * 1e6
        engine_us += rep.elapsed_s * 1e6
        n_total += rep.n_requests
        std = rep.hit_prob_std()
        rows[str(b)] = {}
        for i in range(3):
            pred = rep.hit_prob_at_ranks(i, RANKS)
            ref = TABLE1[b][i]
            rows[str(b)][i] = {
                "sim": pred,
                "sim_std": [float(std[i, r - 1]) for r in RANKS],
                "paper": ref,
            }
            all_pred += pred
            all_ref += ref
    err = mean_rel_err(all_pred, all_ref)
    peak_rss = _peak_rss_compare(scale)
    payload = {
        "preset": "table1",
        "scenarios": scenarios,
        "n_requests_per_combo": n_requests,
        "replications": replications,
        "rows": rows,
        "mean_rel_err_vs_paper": err,
        "engine": rep.backend,
        "engine_requests_per_sec": n_total / max(engine_us / 1e6, 1e-9),
        "peak_rss": peak_rss,
    }
    save_artifact("table1_sim", payload)

    print(
        f"# Table I reproduction (simulated, {n_requests} req/combo x "
        f"{replications} replicas; cells are cross-replica means)"
    )
    print(f"# i  b0  b1  b2   h_1      h_10     h_100    h_1000   (paper in parens)")
    for b in B_GRID:
        for i in range(3):
            pred = rows[str(b)][i]["sim"]
            ref = rows[str(b)][i]["paper"]
            cells = "  ".join(f"{p:.4f}({r:.4f})" for p, r in zip(pred, ref))
            print(f"  {i}  {b[0]:3d} {b[1]:3d} {b[2]:3d}  {cells}")
    print(
        f"# engine throughput: {payload['engine_requests_per_sec']:,.0f} req/s "
        f"(drive loop only, {len(B_GRID)} combos x {n_requests} requests)"
    )
    print(
        f"# peak RSS (one combo): streaming "
        f"{peak_rss['streaming']['peak_rss_delta_mb']:.1f} MB vs dense "
        f"{peak_rss['dense']['peak_rss_delta_mb']:.1f} MB — "
        f"{peak_rss['dense_over_streaming']:.1f}x"
    )
    csv_row(
        "table1_sim",
        total_us / max(n_total, 1),
        f"mean_rel_err={err:.4f}",
    )
    return payload


if __name__ == "__main__":
    main()
