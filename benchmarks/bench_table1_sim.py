"""Paper Table I: empirical hit probabilities of the shared-object cache.

Runs the named ``table1`` preset (J=3, Zipf 0.75/0.5/1.0, unit objects,
B=1000) for every allocation combination ``b in {8,64}^3`` and reports
the hit probability of objects at ranks 1/10/100/1000 per proxy, next to
the paper's values.

Estimator: exact residence-time occupancy (PASTA) instead of realized-hit
counting — variance-free given the trajectory. Engine: whatever backend
``scenario.run()`` picks (the native C loop when a compiler is present;
equivalent event for event to the reference cache, see
``tests/test_fastsim.py``).
"""

from __future__ import annotations

from repro.scenario import get_preset

from .common import (
    B_GRID,
    RANKS,
    TABLE1,
    Timer,
    csv_row,
    mean_rel_err,
    save_artifact,
    section5_scale,
)


def main() -> dict:
    scale = section5_scale()
    rows, scenarios, all_pred, all_ref = {}, {}, [], []
    total_us = 0.0
    engine_us = 0.0
    n_requests = n_total = 0
    for b in B_GRID:
        sc = get_preset("table1", b=b).scaled(*scale)
        scenarios[str(b)] = sc.to_dict()
        n_requests = sc.n_requests
        with Timer() as tm:
            rep = sc.run()
        total_us += tm.seconds * 1e6
        engine_us += rep.elapsed_s * 1e6
        n_total += rep.n_requests
        rows[str(b)] = {}
        for i in range(3):
            pred = rep.hit_prob_at_ranks(i, RANKS)
            ref = TABLE1[b][i]
            rows[str(b)][i] = {"sim": pred, "paper": ref}
            all_pred += pred
            all_ref += ref
    err = mean_rel_err(all_pred, all_ref)
    payload = {
        "preset": "table1",
        "scenarios": scenarios,
        "n_requests_per_combo": n_requests,
        "rows": rows,
        "mean_rel_err_vs_paper": err,
        "engine": rep.backend,
        "engine_requests_per_sec": n_total / max(engine_us / 1e6, 1e-9),
    }
    save_artifact("table1_sim", payload)

    print(f"# Table I reproduction (simulated, {n_requests} req/combo)")
    print(f"# i  b0  b1  b2   h_1      h_10     h_100    h_1000   (paper in parens)")
    for b in B_GRID:
        for i in range(3):
            pred = rows[str(b)][i]["sim"]
            ref = rows[str(b)][i]["paper"]
            cells = "  ".join(f"{p:.4f}({r:.4f})" for p, r in zip(pred, ref))
            print(f"  {i}  {b[0]:3d} {b[1]:3d} {b[2]:3d}  {cells}")
    print(
        f"# engine throughput: {payload['engine_requests_per_sec']:,.0f} req/s "
        f"(drive loop only, {len(B_GRID)} combos x {n_requests} requests)"
    )
    csv_row(
        "table1_sim",
        total_us / max(n_total, 1),
        f"mean_rel_err={err:.4f}",
    )
    return payload


if __name__ == "__main__":
    main()
