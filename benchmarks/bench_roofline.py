"""Roofline report over the dry-run artifact directory.

Prints the full (arch x shape x mesh) three-term table and writes the
aggregate JSON consumed by EXPERIMENTS.md §Roofline. Skips quietly when
``benchmarks/artifacts/dryrun/`` holds no artifacts (the compile sweep
that produces them runs offline, outside this repo's benchmark set).
"""

from __future__ import annotations

from pathlib import Path

from repro.roofline.analysis import format_table, reduce_dir

from .common import ARTIFACTS, Timer, csv_row, save_artifact

DRYRUN_DIR = ARTIFACTS / "dryrun"


def main() -> dict:
    if not DRYRUN_DIR.exists() or not list(DRYRUN_DIR.glob("*.json")):
        print("# no dry-run artifacts found under benchmarks/artifacts/"
              "dryrun/; skipping the roofline table")
        csv_row("roofline", float("nan"), "skipped=no_artifacts")
        return {}
    with Timer() as tm:
        rows = reduce_dir(DRYRUN_DIR)
    print(format_table(rows))
    by_bound = {}
    for r in rows:
        by_bound[r.bottleneck] = by_bound.get(r.bottleneck, 0) + 1
    fits = sum(1 for r in rows if r.memory_ok)
    payload = {
        "n_cells": len(rows),
        "bottleneck_counts": by_bound,
        "fits_hbm": fits,
        "rows": [r.__dict__ for r in rows],
    }
    save_artifact("roofline", payload)
    mean_frac = (
        sum(r.roofline_fraction for r in rows) / len(rows) if rows else 0
    )
    print(f"\n# {len(rows)} cells; bottlenecks: {by_bound}; "
          f"{fits}/{len(rows)} fit 16GB HBM; mean roofline fraction "
          f"{mean_frac:.2%}")
    csv_row("roofline", tm.seconds * 1e6 / max(len(rows), 1),
            f"cells={len(rows)};mean_roofline_frac={mean_frac:.3f}")
    return payload


if __name__ == "__main__":
    main()
