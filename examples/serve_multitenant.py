"""Multi-tenant serving with object sharing, end to end.

    PYTHONPATH=src python examples/serve_multitenant.py

Three tenants share a paged KV pool. Tenants A and B serve overlapping
workloads (common system prompts / RAG chunks); tenant C is disjoint.
The engine admits tenants with the working-set controller, shares prefix
blocks per the paper's LRU-list apportionment, and decodes with a real
(reduced) model. Finally, the shared-prefix Pallas kernel is
demonstrated on a grouped batch against its jnp oracle.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.kernels import ops, ref
from repro.models import make_model
from repro.serving import EngineConfig, Request, ServingEngine, TenantSpec

rng = np.random.default_rng(0)

print("== build engine (qwen3-1.7b reduced, live decode) ==")
cfg = get_config("qwen3-1.7b").reduced()
model = make_model(cfg, compute_dtype=jnp.float32)
params = model.init(jax.random.PRNGKey(0))

ecfg = EngineConfig(block_tokens=8, pool_blocks=512)
from repro.cacheblocks import layout_for

layout = layout_for(cfg, block_tokens=8)
pool_bytes = ecfg.pool_blocks * layout.bytes_per_block
engine = ServingEngine(
    cfg,
    tenants=[  # SLAs sum to 90% of B; sharing frees real headroom beyond
        TenantSpec("tenantA", b_star_bytes=0.35 * pool_bytes),
        TenantSpec("tenantB", b_star_bytes=0.35 * pool_bytes),
        TenantSpec("tenantC", b_star_bytes=0.20 * pool_bytes),
    ],
    engine_cfg=ecfg,
    model=model,
    params=params,
)

# shared system prompts: A and B reuse the same 48-token prefixes
SYSTEM_PROMPTS = [rng.integers(0, cfg.vocab_size, 48) for _ in range(4)]
print("\n== request stream ==")
for step in range(40):
    tenant = rng.choice(["tenantA", "tenantB", "tenantC"], p=[0.4, 0.4, 0.2])
    if tenant in ("tenantA", "tenantB"):
        prefix = SYSTEM_PROMPTS[rng.integers(0, len(SYSTEM_PROMPTS))]
    else:
        prefix = rng.integers(0, cfg.vocab_size, 48)  # disjoint workload
    user = rng.integers(0, cfg.vocab_size, 16)
    tokens = np.concatenate([prefix, user])
    res = engine.submit(tenant, tokens, max_new_tokens=4)
    if step % 8 == 0:
        print(f"  step {step:3d} {tenant}: cached {res.cached_tokens}/"
              f"{len(tokens)} tokens, ripple evictions {res.ripple_evictions}, "
              f"output {res.output[0][:4] if res.output is not None else None}")

s = engine.stats()
print("\n== engine stats ==")
for k, v in s.items():
    print(f"  {k}: {v:.4g}" if isinstance(v, float) else f"  {k}: {v}")

print("\n== shared-prefix kernel (object sharing on the MXU) ==")
P_, M, H, D, S = 2, 4, cfg.n_heads, cfg.head_dim, 64
kq = jax.random.split(jax.random.PRNGKey(1), 4)
q = jax.random.normal(kq[0], (P_, M, H, D))
pk = jax.random.normal(kq[1], (P_, S, cfg.n_kv_heads, D))
pv = jax.random.normal(kq[2], (P_, S, cfg.n_kv_heads, D))
plens = jnp.array([S, S // 2], jnp.int32)
out, lse = ops.shared_prefix_attention(q, pk, pv, plens, interpret=True)
want, want_lse = ref.reference_shared_prefix_attention(q, pk, pv, plens)
err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - want)))
print(f"  grouped prefix attention: {P_} shared objects x {M} requests, "
      f"kernel-vs-oracle err {err:.2e}")
print("  -> the physical prefix KV is read ONCE per group: the compute "
      "analogue of the paper's l_n/|P(n)| cost sharing")
