"""End-to-end training driver: a ~100M-parameter LM for a few hundred
steps with checkpointing + failure recovery.

    PYTHONPATH=src python examples/train_lm.py             # container scale
    PYTHONPATH=src python examples/train_lm.py --full      # true ~100M

The container is a single CPU core, so the default run trains a
structure-preserving ~10M-param xlstm config (same code path, ~2 min);
``--full`` runs the real xlstm-125m for the same number of steps (slow
on CPU, the intended target is a TPU slice via launch/train.py).
"""

import sys

from repro.launch.train import main as train_main

full = "--full" in sys.argv
args = [
    "--arch", "xlstm-125m",
    "--steps", "300",
    "--batch", "8",
    "--seq", "128",
    "--lr", "3e-3",
    "--ckpt-dir", "/tmp/repro_train_lm",
    "--ckpt-every", "100",
    "--log-every", "25",
]
if not full:
    args.append("--reduced")
sys.exit(train_main(args))
