"""Quickstart: the paper's object-sharing cache in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

1. Build a shared cache for 3 proxies (Zipf demand), run an IRM trace.
2. Compare measured hit probabilities against the working-set
   approximation (paper Tables I vs II).
3. Show overbooking: virtual allocations + eq. (13) admission.
"""

import numpy as np

from repro.core import (
    AdmissionController,
    GetResult,
    SharedLRUCache,
    rate_matrix,
    sample_trace,
    solve_workingset,
    virtual_allocations,
)
from repro.core.metrics import OccupancyRecorder

N, B = 1000, 1000
ALPHAS = (0.75, 0.5, 1.0)
ALLOC = (64, 64, 8)

print("== 1. simulate the shared cache ==")
lam = rate_matrix(N, ALPHAS)
trace = sample_trace(lam, 400_000, seed=1)
cache = SharedLRUCache(list(ALLOC), physical_capacity=B)
rec = OccupancyRecorder(3, N).attach_to(cache)
for idx, (i, k) in enumerate(zip(trace.proxies.tolist(), trace.objects.tolist())):
    rec.now = idx
    if idx == 40_000:
        rec.reset_window()
    if cache.get(i, k).result is GetResult.MISS:
        cache.set(i, k, 1)
rec.now = len(trace)
rec.finalize()
h_sim = rec.occupancy()
print(f"cache state: {cache}")

print("\n== 2. working-set approximation (paper eq. 8 + eq. 5) ==")
sol = solve_workingset(lam, np.ones(N), np.array(ALLOC, float), attribution="L1")
print("rank:        1       10      100")
for i in range(3):
    sim = [h_sim[i, r - 1] for r in (1, 10, 100)]
    ws = [sol.h[i, r - 1] for r in (1, 10, 100)]
    print(f"proxy {i} sim  " + "  ".join(f"{x:.4f}" for x in sim))
    print(f"proxy {i} ws   " + "  ".join(f"{x:.4f}" for x in ws))

print("\n== 3. overbooking + admission (paper Section IV-C) ==")
b_star = np.array([64.0, 64.0, 64.0])
b_virtual, _ = virtual_allocations(lam, np.ones(N), b_star)
print(f"SLA allocations b*      = {b_star}")
print(f"virtual allocations b   = {np.round(b_virtual, 1)}")
print(f"overbooking factor      = {b_star.sum() / b_virtual.sum():.3f}x")

ctl = AdmissionController(physical_capacity=150.0, lengths=np.ones(N))
for i in range(3):
    d = ctl.admit(f"proxy{i}", 64.0)
    print(f"admit proxy{i} (b*=64): {d.admitted} ({d.reason})")
    if d.admitted:
        ctl.observe(f"proxy{i}", lam[min(i, 2)])
        ctl.refresh()
print(f"committed SLA {ctl.committed_sla:.0f} vs B={ctl.B:.0f} "
      f"-> overbooked={ctl.overbooked}")
