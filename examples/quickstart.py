"""Quickstart: the paper's object-sharing cache in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

One declarative `Scenario` = workload x system x estimator:

1. Run the `quickstart` preset under BOTH estimators — Monte-Carlo
   simulation and the working-set approximation — and compare (paper
   Tables I vs II in miniature).
2. Swap one axis: the not-shared baseline on the same trace (Prop. 3.1).
3. Serialize the scenario to JSON and rerun it bit-identically.
4. Overbooking + eq. (13) admission control (paper Section IV-C).
5. The same admission loop as an online scenario: tenants arrive and
   depart, virtual allocations refresh from estimated popularities, and
   the final admitted set is validated by simulation.

The older entry points (`SharedLRUCache`, `SimParams`/`simulate_trace`,
`solve_workingset`, `MCDOSServer`) all still work — `Scenario.run()` is
a declarative front door over exactly those engines, and
`tests/test_fastsim.py` keeps them event-equivalent.
"""

import dataclasses

import numpy as np

from repro.core import AdmissionController, virtual_allocations
from repro.scenario import Scenario, System, get_preset

print("== 1. one scenario, two estimators ==")
sc = get_preset("quickstart")          # J=3 Zipf IRM, b=(64,64,8), B=1000
sim = sc.run()                         # Monte-Carlo (fast C/Python engine)
ws = sc.with_estimator("working_set").run()  # eq. (8) fixed point (JAX)

print(f"scenario: {sc.name} ({sc.n_requests:,} requests, "
      f"backend {sim.backend})")
print("rank:        1       10      100")
for i in range(3):
    print(f"proxy {i} sim  "
          + "  ".join(f"{x:.4f}" for x in sim.hit_prob_at_ranks(i, (1, 10, 100))))
    print(f"proxy {i} ws   "
          + "  ".join(f"{x:.4f}" for x in ws.hit_prob_at_ranks(i, (1, 10, 100))))
print(f"overall hit rate: sim={sim.overall_hit_rate:.4f} "
      f"ws={ws.overall_hit_rate:.4f}")

print("\n== 2. swap the system axis: not-shared baseline, same trace ==")
ns = dataclasses.replace(
    sc, system=System(variant="noshare", allocations=sc.system.allocations)
).run()
gain = sim.hit_rate - ns.hit_rate
print("per-proxy hit-rate gain from sharing: "
      + "  ".join(f"{g:+.4f}" for g in gain))

print("\n== 3. JSON round trip ==")
clone = Scenario.from_json(sc.to_json())
assert clone.run().same_estimates(sim)
print(f"Scenario.from_json(sc.to_json()).run() reproduces the Report "
      f"bit for bit ({len(sc.to_json())} bytes of JSON)")

print("\n== 4. overbooking + admission (paper Section IV-C) ==")
lam = sc.workload.rates()
N = sc.workload.n_objects
b_star = np.array([64.0, 64.0, 64.0])
b_virtual, _ = virtual_allocations(lam, np.ones(N), b_star)
print(f"SLA allocations b*      = {b_star}")
print(f"virtual allocations b   = {np.round(b_virtual, 1)}")
print(f"overbooking factor      = {b_star.sum() / b_virtual.sum():.3f}x")

ctl = AdmissionController(physical_capacity=150.0, lengths=np.ones(N))
for i in range(3):
    d = ctl.admit(f"proxy{i}", 64.0)
    print(f"admit proxy{i} (b*=64): {d.admitted} ({d.reason})")
    if d.admitted:
        ctl.observe(f"proxy{i}", lam[min(i, 2)])
        ctl.refresh()
print(f"committed SLA {ctl.committed_sla:.0f} vs B={ctl.B:.0f} "
      f"-> overbooked={ctl.overbooked}")

print("\n== 5. admission control as an online scenario ==")
adm_sc = get_preset("admission_overbooking").scaled(requests=0.01)
adm = adm_sc.run().extras["admission"]
n_static = int(adm["capacity"] // max(adm["b_star"].values()))
print(f"episode: {len(adm['decisions'])} decisions -> "
      f"{len(adm['active_tenants'])} tenants active at "
      f"B={adm['capacity']:.0f} (static partitioning fits {n_static})")
print(f"overbooking gain sum b*/sum b = {adm['overbooking_gain']:.3f}; "
      f"max |realized - predicted| SLA hit rate = "
      f"{adm['max_abs_sla_gap']:.4f}")
